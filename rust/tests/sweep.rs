//! Integration: the parallel experiment engine through its public API
//! and the `hyplacer sweep` CLI (table + JSON emission, fast failure on
//! bad axes).


#![allow(clippy::field_reassign_with_default)]
use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::exec::SweepSpec;
use hyplacer::report::json;

fn quick_spec() -> SweepSpec {
    let mut sim = SimConfig::default();
    sim.epochs = 5;
    sim.warmup_epochs = 1;
    let mut spec = SweepSpec::new(MachineConfig::paper_machine(), sim, HyPlacerConfig::default());
    spec.workloads = vec!["cg-S".to_string()];
    spec.policies = vec!["adm-default".to_string(), "memm".to_string()];
    spec.seeds = vec![1, 2];
    spec
}

#[test]
fn sweep_is_thread_count_invariant_via_public_api() {
    let spec = quick_spec();
    let a = spec.run(1).unwrap();
    let b = spec.run(3).unwrap();
    assert_eq!(a.results.len(), 4);
    for (x, y) in a.results.iter().zip(b.results.iter()) {
        assert_eq!(x.policy, y.policy);
        assert_eq!(x.seed, y.seed);
        assert_eq!(x.sim.total_wall_secs.to_bits(), y.sim.total_wall_secs.to_bits());
        assert_eq!(x.sim.migrated_pages, y.sim.migrated_pages);
    }
}

#[test]
fn cli_sweep_reports_table_and_json() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let json_path = std::env::temp_dir().join("hyplacer_sweep_cli_test.json");
    let out = std::process::Command::new(exe)
        .args([
            "sweep",
            "-w",
            "cg-S",
            "-p",
            "adm-default,memm",
            "--seeds",
            "1,2",
            "--jobs",
            "2",
            "--epochs",
            "4",
            "--json",
            json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cells") && text.contains("memm"), "{text}");

    let doc = json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    assert!(cells[0].get("policy").unwrap().as_str().is_some());
    std::fs::remove_file(&json_path).ok();
}

#[test]
fn public_api_json_round_trip_and_resume() {
    use hyplacer::exec::SweepRun;
    let spec = quick_spec();
    let first = spec.run_with_cache(2, None).unwrap();
    assert_eq!(first.executed, 4);
    // to_json -> parse -> from_json == original (byte-identical re-render)
    let rendered = first.run.to_json().render();
    let prior = SweepRun::from_json(&json::parse(&rendered).unwrap()).unwrap();
    assert_eq!(prior.to_json().render(), rendered);
    // resuming from the round-tripped document executes nothing
    let resumed = spec.run_with_cache(2, Some(&prior)).unwrap();
    assert_eq!(resumed.executed, 0);
    assert_eq!(resumed.cached, 4);
    assert_eq!(resumed.run.to_json().render(), rendered);
}

#[test]
fn cli_sweep_resume_executes_zero_cells_and_rewrites_identical_bytes() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out_path = std::env::temp_dir().join("hyplacer_sweep_resume_test.json");
    let out_str = out_path.to_str().unwrap().to_string();
    std::fs::remove_file(&out_path).ok();
    let run = |resume: bool| {
        let mut cmd = std::process::Command::new(exe);
        cmd.args([
            "sweep",
            "-w",
            "cg-S",
            "-p",
            "adm-default,memm",
            "--seeds",
            "1,2",
            "--jobs",
            "2",
            "--epochs",
            "4",
            "--out",
            &out_str,
        ]);
        if resume {
            cmd.arg("--resume");
        }
        cmd.output().unwrap()
    };
    let first = run(false);
    assert!(first.status.success(), "stderr: {}", String::from_utf8_lossy(&first.stderr));
    assert!(
        String::from_utf8_lossy(&first.stdout).contains("executed 4 of 4 cells"),
        "{}",
        String::from_utf8_lossy(&first.stdout)
    );
    let bytes_first = std::fs::read(&out_path).unwrap();

    let second = run(true);
    assert!(second.status.success(), "stderr: {}", String::from_utf8_lossy(&second.stderr));
    assert!(
        String::from_utf8_lossy(&second.stdout).contains("executed 0 of 4 cells (4 cached)"),
        "{}",
        String::from_utf8_lossy(&second.stdout)
    );
    let bytes_second = std::fs::read(&out_path).unwrap();
    assert_eq!(bytes_first, bytes_second, "resumed rewrite must be byte-identical");
    std::fs::remove_file(&out_path).ok();
}

#[test]
fn cli_sweep_epochs_for_override_invalidates_matching_cells_only() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out_path = std::env::temp_dir().join("hyplacer_sweep_override_test.json");
    let out_str = out_path.to_str().unwrap().to_string();
    std::fs::remove_file(&out_path).ok();
    let run = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(exe);
        cmd.args([
            "sweep", "-w", "cg-S,mg-S", "-p", "adm-default", "--seeds", "1", "--epochs", "4",
            "--out", &out_str,
        ]);
        cmd.args(extra);
        let out = cmd.output().unwrap();
        assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    // cold run with a per-cell override: everything executes
    let s = run(&["--epochs-for", "mg-*=3"]);
    assert!(s.contains("executed 2 of 2 cells"), "{s}");
    // identical spec resumes fully cached
    let s = run(&["--epochs-for", "mg-*=3", "--resume"]);
    assert!(s.contains("executed 0 of 2 cells (2 cached)"), "{s}");
    // dropping the override changes exactly the mg-S cell's key
    let s = run(&["--resume"]);
    assert!(s.contains("executed 1 of 2 cells (1 cached)"), "{s}");
    std::fs::remove_file(&out_path).ok();
}

#[test]
fn sweep_accepts_gap_workloads_via_public_api() {
    // the GAP suite (PageRank/BFS) is on the sweep allowlist alongside
    // the NPB set — the prerequisite for the ROADMAP's GAP evaluation
    // figure
    let mut spec = quick_spec();
    spec.workloads = vec!["pr-S".to_string(), "bfs-S".to_string()];
    spec.policies = vec!["adm-default".to_string(), "hyplacer".to_string()];
    spec.seeds = vec![1];
    spec.validate().unwrap();
    let run = spec.run(2).unwrap();
    assert_eq!(run.results.len(), 4);
    for cell in &run.results {
        assert!(cell.sim.total_wall_secs > 0.0, "{}/{}", cell.workload, cell.policy);
        assert!(cell.sim.total_app_bytes > 0.0);
    }
    // display names resolve through the registry
    assert!(run.results.iter().any(|c| c.sim.workload == "PR-S"));
    assert!(run.results.iter().any(|c| c.sim.workload == "BFS-S"));
    // hyplacer cells normalize against their adm-default baseline
    let hyp = run
        .results
        .iter()
        .find(|c| c.policy == "hyplacer" && c.workload == "pr-S")
        .unwrap();
    assert!(run.speedup_vs_baseline(hyp).is_some());
}

#[test]
fn cli_sweep_accepts_gap_workloads() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out = std::process::Command::new(exe)
        .args([
            "sweep", "-w", "pr-S,bfs-S", "-p", "adm-default", "--seeds", "1", "--jobs", "2",
            "--epochs", "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("PR-S") && text.contains("BFS-S"), "{text}");
    assert!(text.contains("executed 2 of 2 cells"), "{text}");

    // the "gap" suite alias expands to the whole suite at -M
    let out = std::process::Command::new(exe)
        .args(["sweep", "-w", "gap", "-p", "adm-default", "--seeds", "1", "--jobs", "2",
               "--epochs", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("PR-M") && text.contains("BFS-M"), "{text}");
}

#[test]
fn cli_sweep_migrate_share_override_rekeys_matching_cells_only() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out_path = std::env::temp_dir().join("hyplacer_sweep_mshare_test.json");
    let out_str = out_path.to_str().unwrap().to_string();
    std::fs::remove_file(&out_path).ok();
    let run = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(exe);
        cmd.args([
            "sweep", "-w", "cg-S,mg-S", "-p", "adm-default", "--seeds", "1", "--epochs", "4",
            "--out", &out_str,
        ]);
        cmd.args(extra);
        let out = cmd.output().unwrap();
        assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    // cold run at default (unthrottled) share
    let s = run(&[]);
    assert!(s.contains("executed 2 of 2 cells"), "{s}");
    // throttling one workload's cells re-executes exactly those
    let s = run(&["--migrate-share-for", "mg-*=0.5", "--resume"]);
    assert!(s.contains("executed 1 of 2 cells (1 cached)"), "{s}");
    // and the explicit default share maps to the legacy keys (all cached)
    let s = run(&["--resume"]);
    assert!(s.contains("executed 0 of 2 cells (2 cached)"), "{s}");
    std::fs::remove_file(&out_path).ok();

    // malformed rules fail fast
    let out = std::process::Command::new(exe)
        .args(["sweep", "-w", "cg-S", "--migrate-share-for", "cg-*=2.0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("migrate share"));
}

#[test]
fn cli_fig_gap_emits_artifact_and_resumes() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out_path = std::env::temp_dir().join("hyplacer_fig_gap_cli_test.json");
    let out_str = out_path.to_str().unwrap().to_string();
    std::fs::remove_file(&out_path).ok();
    let run = |resume: bool| {
        let mut cmd = std::process::Command::new(exe);
        cmd.args([
            "fig-gap", "--quick", "--epochs", "6", "--jobs", "2", "--out", &out_str,
        ]);
        if resume {
            cmd.arg("--resume");
        }
        cmd.output().unwrap()
    };
    let first = run(false);
    assert!(first.status.success(), "stderr: {}", String::from_utf8_lossy(&first.stderr));
    let text = String::from_utf8_lossy(&first.stdout);
    assert!(text.contains("fig-gap") && text.contains("PR-M") && text.contains("BFS-L"), "{text}");

    // the JSON artifact is the standard sweep-results schema
    let doc = json::parse(&std::fs::read_to_string(&out_path).unwrap()).unwrap();
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4 * 6, "PR/BFS x M/L x fig5 policy set");
    assert!(cells
        .iter()
        .any(|c| c.get("workload").unwrap().as_str() == Some("PR-L")));
    let bytes_first = std::fs::read(&out_path).unwrap();

    // resuming re-executes nothing and rewrites identical bytes
    let second = run(true);
    assert!(second.status.success(), "stderr: {}", String::from_utf8_lossy(&second.stderr));
    assert_eq!(bytes_first, std::fs::read(&out_path).unwrap());
    std::fs::remove_file(&out_path).ok();
}

#[test]
fn cli_sweep_rejects_duplicate_axes_and_lone_resume() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out = std::process::Command::new(exe)
        .args(["sweep", "-w", "cg-S,cg-S", "-p", "adm-default"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("duplicate"));

    let out = std::process::Command::new(exe)
        .args(["sweep", "--resume"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--out"));
}

#[test]
fn cli_sweep_fails_fast_on_bad_axes() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out = std::process::Command::new(exe)
        .args(["sweep", "-w", "nope-Q"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("nope-Q"));

    let out = std::process::Command::new(exe)
        .args(["sweep", "--machines", "4:4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
