//! Integration: the parallel experiment engine through its public API
//! and the `hyplacer sweep` CLI (table + JSON emission, fast failure on
//! bad axes).


#![allow(clippy::field_reassign_with_default)]
use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::exec::SweepSpec;
use hyplacer::report::json;

fn quick_spec() -> SweepSpec {
    let mut sim = SimConfig::default();
    sim.epochs = 5;
    sim.warmup_epochs = 1;
    let mut spec = SweepSpec::new(MachineConfig::paper_machine(), sim, HyPlacerConfig::default());
    spec.workloads = vec!["cg-S".to_string()];
    spec.policies = vec!["adm-default".to_string(), "memm".to_string()];
    spec.seeds = vec![1, 2];
    spec
}

#[test]
fn sweep_is_thread_count_invariant_via_public_api() {
    let spec = quick_spec();
    let a = spec.run(1).unwrap();
    let b = spec.run(3).unwrap();
    assert_eq!(a.results.len(), 4);
    for (x, y) in a.results.iter().zip(b.results.iter()) {
        assert_eq!(x.policy, y.policy);
        assert_eq!(x.seed, y.seed);
        assert_eq!(x.sim.total_wall_secs.to_bits(), y.sim.total_wall_secs.to_bits());
        assert_eq!(x.sim.migrated_pages, y.sim.migrated_pages);
    }
}

#[test]
fn cli_sweep_reports_table_and_json() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let json_path = std::env::temp_dir().join("hyplacer_sweep_cli_test.json");
    let out = std::process::Command::new(exe)
        .args([
            "sweep",
            "-w",
            "cg-S",
            "-p",
            "adm-default,memm",
            "--seeds",
            "1,2",
            "--jobs",
            "2",
            "--epochs",
            "4",
            "--json",
            json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cells") && text.contains("memm"), "{text}");

    let doc = json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    assert!(cells[0].get("policy").unwrap().as_str().is_some());
    std::fs::remove_file(&json_path).ok();
}

#[test]
fn cli_sweep_fails_fast_on_bad_axes() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out = std::process::Command::new(exe)
        .args(["sweep", "-w", "nope-Q"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("nope-Q"));

    let out = std::process::Command::new(exe)
        .args(["sweep", "--machines", "4:4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
