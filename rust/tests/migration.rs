//! Migration-engine integration: the bit-identity contract between the
//! bandwidth-throttled [`MigrationEngine`] and the one-shot
//! `migrate::execute` reference at `migrate_share = 1.0`, the throttled
//! carry-over/convergence semantics, and the fig5-policy lockstep proof
//! that the coordinator swap changed nothing at default config.

use hyplacer::config::{HyPlacerConfig, MachineConfig, Tier};
use hyplacer::mem::PcmonSnapshot;
use hyplacer::policies::{self, Policy, PolicyCtx, FIG5_POLICIES};
use hyplacer::util::proptest::check;
use hyplacer::vm::{migrate, MigrationEngine, MigrationPlan, PageTable};

fn small_cfg() -> MachineConfig {
    let mut cfg = MachineConfig::paper_machine();
    cfg.page_bytes = 1024;
    cfg.migrate_page_overhead = 1e-6;
    cfg
}

fn flags_equal(a: &PageTable, b: &PageTable) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("table sizes differ: {} vs {}", a.len(), b.len()));
    }
    for p in 0..a.len() {
        if a.flags(p).0 != b.flags(p).0 {
            return Err(format!(
                "page {p}: engine flags {:#04x} vs one-shot {:#04x}",
                a.flags(p).0,
                b.flags(p).0
            ));
        }
    }
    if a.used_pages(Tier::Dram) != b.used_pages(Tier::Dram)
        || a.used_pages(Tier::Pm) != b.used_pages(Tier::Pm)
    {
        return Err("occupancy counters diverged".to_string());
    }
    Ok(())
}

fn stats_equal(
    a: &hyplacer::vm::MigrationStats,
    b: &hyplacer::vm::MigrationStats,
) -> Result<(), String> {
    if a.promoted != b.promoted
        || a.demoted != b.demoted
        || a.exchanged_pairs != b.exchanged_pairs
        || a.skipped != b.skipped
        || a.stale != 0
    {
        return Err(format!("outcome counters diverged: engine {a:?} vs one-shot {b:?}"));
    }
    let pairs = [
        (a.dram_traffic.read_bytes, b.dram_traffic.read_bytes),
        (a.dram_traffic.write_bytes, b.dram_traffic.write_bytes),
        (a.pm_traffic.read_bytes, b.pm_traffic.read_bytes),
        (a.pm_traffic.write_bytes, b.pm_traffic.write_bytes),
        (a.overhead_secs, b.overhead_secs),
    ];
    for (x, y) in pairs {
        if x.to_bits() != y.to_bits() {
            return Err(format!("cost diverged: engine {x} vs one-shot {y}"));
        }
    }
    Ok(())
}

/// Property: at `migrate_share = 1.0`, submit + run_epoch reproduces the
/// one-shot `execute` bit for bit — same final page table, same stats —
/// for arbitrary well-formed (dup-free) plans over arbitrary tables,
/// including malformed entries (wrong tiers, capacity overruns) that
/// exercise the skip paths.
#[test]
fn unthrottled_engine_is_bit_identical_to_oneshot_execute() {
    let cfg = small_cfg();
    check("engine ≡ one-shot at share 1.0", 80, |rng| {
        let pages = 32 + rng.next_below(200) as u32;
        let dram_cap = 4 + rng.next_below(pages as u64);
        let pm_cap = 8 + rng.next_below(2 * pages as u64);
        let mut pt = PageTable::new(pages, 1024, dram_cap * 1024, pm_cap * 1024);
        for p in 0..pages {
            let tier = if rng.chance(0.4) { Tier::Dram } else { Tier::Pm };
            if !pt.allocate(p, tier) && !pt.allocate(p, tier.other()) {
                break; // both tiers full: leave the rest unmapped
            }
        }
        // a dup-free plan drawn from a shuffled page universe; roles are
        // assigned blindly, so wrong-tier/invalid entries are common
        let mut order: Vec<u32> = (0..pages).collect();
        for i in (1..order.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let mut it = order.into_iter();
        let mut plan = MigrationPlan::default();
        for _ in 0..rng.next_below(12) {
            if let Some(p) = it.next() {
                plan.demote.push(p);
            }
        }
        for _ in 0..rng.next_below(6) {
            if let (Some(a), Some(b)) = (it.next(), it.next()) {
                plan.exchange.push((a, b));
            }
        }
        for _ in 0..rng.next_below(12) {
            if let Some(p) = it.next() {
                plan.promote.push(p);
            }
        }
        plan.validate().map_err(|e| format!("generator produced a dup: {e}"))?;

        let mut oneshot = pt.clone();
        let ref_stats = migrate::execute(&mut oneshot, &cfg, &plan);

        let mut eng = MigrationEngine::new(1.0);
        eng.submit(&mut pt, &plan, 0);
        let (eng_stats, executed) = eng.run_epoch(&mut pt, &cfg, 0, 1.0);

        flags_equal(&pt, &oneshot)?;
        stats_equal(&eng_stats, &ref_stats)?;
        hyplacer::prop_assert!(
            eng.backpressure().is_idle(),
            "unthrottled queue must drain within the epoch"
        );
        hyplacer::prop_assert!(
            executed.page_moves() == eng_stats.moves(),
            "executed plan must list exactly the landed moves"
        );
        Ok(())
    });
}

/// The fig5 policy set, driven lockstep: each epoch the policy ticks on
/// the engine-backed table, and the resulting plan is applied both ways
/// — through the unthrottled engine and through the one-shot reference
/// on a post-tick snapshot. Any divergence in PTE state or cost would
/// make post-refactor `SimResult`s differ from pre-refactor ones; none
/// is allowed. (The coordinator is otherwise unchanged, so this plus
/// the property test above is the SimResult bit-identity argument.)
#[test]
fn fig5_policies_engine_matches_oneshot_per_epoch() {
    let cfg = small_cfg();
    let hp = HyPlacerConfig::default();
    let total: u32 = 256;
    for pname in FIG5_POLICIES {
        let mut policy = policies::by_name(pname, &cfg, &hp).unwrap();
        let mut pt = PageTable::new(total, 1024, 64 * 1024, 512 * 1024);
        for page in 0..total {
            let want = policy.place_new(page, &pt);
            assert!(pt.allocate(page, want) || pt.allocate(page, want.other()));
        }
        let mut eng = MigrationEngine::new(1.0);
        for epoch in 0..12u32 {
            // deterministic rotating touch pattern (writes + delay window)
            for i in 0..48u32 {
                let page = (i * 5 + epoch * 7) % total;
                let write = (i + epoch) % 3 == 0;
                pt.touch(page, write);
                if i % 4 == 0 {
                    pt.touch_window(page, write);
                }
            }
            // alternate PCMon regimes to exercise several decision modes
            let pcmon = if epoch % 2 == 0 {
                PcmonSnapshot {
                    dram_read_bw: 1e9,
                    pm_read_bw: 10e9,
                    pm_write_bw: 50e6,
                    window_secs: 1.0,
                    window_id: epoch as u64 + 1,
                    ..Default::default()
                }
            } else {
                PcmonSnapshot::default()
            };
            let plan = {
                let mut ctx = PolicyCtx {
                    pt: &mut pt,
                    pcmon,
                    cfg: &cfg,
                    epoch,
                    epoch_secs: 1.0,
                    backpressure: eng.backpressure(),
                    tenants: &[],
                };
                policy.epoch_tick(&mut ctx)
            };
            plan.validate()
                .unwrap_or_else(|e| panic!("{pname} produced an ill-formed plan: {e}"));

            // one-shot reference on a post-tick snapshot
            let mut oneshot = pt.clone();
            let ref_stats = migrate::execute(&mut oneshot, &cfg, &plan);
            // engine path on the live table
            eng.submit(&mut pt, &plan, epoch);
            let (eng_stats, _) = eng.run_epoch(&mut pt, &cfg, epoch, 1.0);

            flags_equal(&pt, &oneshot).unwrap_or_else(|e| panic!("{pname} epoch {epoch}: {e}"));
            let verdict = stats_equal(&eng_stats, &ref_stats);
            verdict.unwrap_or_else(|e| panic!("{pname} epoch {epoch}: {e}"));
            assert!(eng.backpressure().is_idle(), "{pname} epoch {epoch}: queue not empty");
        }
    }
}

/// Convergence: once the workload quiesces, a throttled run drains its
/// carry-over queue and reaches exactly the placement the unthrottled
/// run reached immediately.
#[test]
fn throttled_run_converges_to_unthrottled_placement_after_quiesce() {
    let cfg = small_cfg();
    let hp = HyPlacerConfig::default();
    // budget of 2 moves/epoch for the throttled run
    let share = 2.0 * cfg.page_bytes as f64 / cfg.pm.peak_write_bw();
    assert_eq!(MigrationEngine::budget_moves(&cfg, share, 1.0), 2);

    let run = |share: f64| -> (PageTable, u32) {
        let mut policy = policies::by_name("nimble", &cfg, &hp).unwrap();
        // all 60 pages start in PM; DRAM has room for the hot set
        let mut pt = PageTable::new(60, 1024, 16 * 1024, 128 * 1024);
        for p in 0..60 {
            assert!(pt.allocate(p, Tier::Pm));
        }
        let mut eng = MigrationEngine::new(share);
        let mut epochs_with_moves = 0u32;
        for epoch in 0..30u32 {
            if epoch < 5 {
                // active phase: pages 20..28 are the hot set
                for p in 20..28u32 {
                    pt.touch(p, p % 2 == 0);
                }
            } // epochs >= 5: the workload has quiesced
            let plan = {
                let mut ctx = PolicyCtx {
                    pt: &mut pt,
                    pcmon: PcmonSnapshot::default(),
                    cfg: &cfg,
                    epoch,
                    epoch_secs: 1.0,
                    backpressure: eng.backpressure(),
                    tenants: &[],
                };
                policy.epoch_tick(&mut ctx)
            };
            eng.submit(&mut pt, &plan, epoch);
            let (stats, _) = eng.run_epoch(&mut pt, &cfg, epoch, 1.0);
            if stats.moves() > 0 {
                epochs_with_moves += 1;
            }
        }
        assert!(eng.backpressure().is_idle(), "queue must drain after quiesce");
        (pt, epochs_with_moves)
    };

    let (fast, fast_epochs) = run(1.0);
    let (slow, slow_epochs) = run(share);
    // the throttled run really was spread across epochs...
    assert!(slow_epochs > fast_epochs, "throttle had no effect: {slow_epochs} vs {fast_epochs}");
    // ...yet lands every page in the same final tier
    for p in 0..60u32 {
        assert_eq!(
            fast.flags(p).tier(),
            slow.flags(p).tier(),
            "page {p} placed differently"
        );
    }
    assert_eq!(fast.used_pages(Tier::Dram), 8, "the hot set ends up in DRAM");
}
