//! Fault-injection integration: the bit-identity contract of the
//! no-fault path across the fig5 policy set (including the armed-but-
//! neutral plan that exercises every fault branch with ×1.0 derates),
//! the no-livelock guarantee under a sustained brownout + copy-failure
//! storm, the PINNED-exclusion contract at the policy level, and
//! run-level migration-stat conservation under random fault plans ×
//! random policies.

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig, Tier};
use hyplacer::coordinator::{run_pair, SimResult};
use hyplacer::faults::{self, Brownout, FaultPlan};
use hyplacer::mem::PcmonSnapshot;
use hyplacer::policies::{self, PolicyCtx, FIG5_POLICIES};
use hyplacer::util::proptest::check;
use hyplacer::vm::{MigrationEngine, PageTable};
use hyplacer::workloads;

fn run_with(policy: &str, workload: &str, epochs: u32, faults: FaultPlan) -> SimResult {
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = epochs;
    sim.warmup_epochs = 2;
    sim.faults = faults;
    let hp = HyPlacerConfig::default();
    let w = workloads::by_name(workload, cfg.page_bytes, sim.epoch_secs).unwrap();
    let p = policies::by_name(policy, &cfg, &hp).unwrap();
    run_pair(&cfg, &sim, w, p, 0.05)
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    let f64_pairs = [
        ("total_wall_secs", a.total_wall_secs, b.total_wall_secs),
        ("throughput", a.throughput, b.throughput),
        ("steady_throughput", a.steady_throughput, b.steady_throughput),
        ("energy_j_per_byte", a.energy_j_per_byte, b.energy_j_per_byte),
        ("total_energy_j", a.total_energy_j, b.total_energy_j),
        ("dram_traffic_share", a.dram_traffic_share, b.dram_traffic_share),
    ];
    for (name, x, y) in f64_pairs {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name} diverged: {x} vs {y}");
    }
    assert_eq!(a.migrated_pages, b.migrated_pages, "{ctx}: migrated_pages");
    assert_eq!(a.migrate_queue_peak, b.migrate_queue_peak, "{ctx}: queue peak");
    assert_eq!(a.stats.epochs.len(), b.stats.epochs.len(), "{ctx}: epoch count");
    for (ea, eb) in a.stats.epochs.iter().zip(&b.stats.epochs) {
        assert_eq!(
            ea.wall_secs.to_bits(),
            eb.wall_secs.to_bits(),
            "{ctx}: epoch {} wall time diverged",
            ea.epoch
        );
    }
}

/// The tentpole's bit-identity contract, lockstep over the fig5 policy
/// set: the default (empty) fault plan and an *armed but neutral* plan —
/// a factor-1.0 brownout, which takes every fault-gated branch in the
/// coordinator (`set_pm_derate`, engine installation with a zero copy
/// rate, per-epoch window checks) while injecting nothing — produce
/// bitwise-equal results. This is the strongest executable form of "the
/// no-fault path is unchanged": the fault machinery itself, fully wired,
/// is invisible at neutral settings.
#[test]
fn no_fault_path_is_bit_identical_across_the_fig5_policy_set() {
    let neutral = FaultPlan::parse("brownout:ep2..6*1.0").expect("neutral plan parses");
    assert!(!neutral.is_none(), "a windowed plan must arm the fault paths");
    for pname in FIG5_POLICIES {
        let clean = run_with(pname, "cg-M", 8, FaultPlan::none());
        let armed = run_with(pname, "cg-M", 8, neutral.clone());
        assert_bit_identical(&clean, &armed, pname);
        for r in [&clean, &armed] {
            assert_eq!(r.migrate_retried, 0, "{pname}: no-fault run retried");
            assert_eq!(r.migrate_failed, 0, "{pname}: no-fault run failed moves");
            assert_eq!(r.safe_mode_epochs, 0, "{pname}: no-fault run hit safe mode");
            assert_eq!(r.stats.migrate_pinned_rejected_total(), 0);
        }
    }
}

/// The acceptance-criteria storm: every fault class at once, sustained
/// past the safe-mode entry threshold. The run must complete (the
/// per-epoch scan bound + bounded retry ladder is the no-livelock
/// argument in DESIGN.md §13) with nonzero retried/failed counts and
/// nonzero safe-mode dwell — and still serve exactly the workload's
/// fixed demand.
#[test]
fn fault_storm_completes_without_livelock_and_reports_degradation() {
    let storm = FaultPlan::parse("copy:0.6,pin:0.01,brownout:ep8..16*0.5,scan-gap:0.1")
        .expect("storm plan parses");
    let r = run_with("hyplacer", "cg-M", 24, storm);
    assert_eq!(r.stats.epochs.len(), 24, "the storm run must complete every epoch");
    assert!(r.total_wall_secs.is_finite() && r.total_wall_secs > 0.0);
    assert!(r.migrate_retried > 0, "a 60% copy-failure storm must retry");
    assert!(r.migrate_failed > 0, "sustained failure must exhaust some retry ladders");
    assert!(r.safe_mode_epochs > 0, "HyPlacer must back off into safe mode");
    assert!(
        r.safe_mode_epochs < 24,
        "safe mode must not start before any failure was observed"
    );
    assert_eq!(r.stats.migrate_pinned_rejected_total(), 0, "policies never plan pinned pages");
    // fixed work: faults slow the run down, they do not shrink it
    let clean = run_with("hyplacer", "cg-M", 24, FaultPlan::none());
    assert_eq!(r.total_app_bytes.to_bits(), clean.total_app_bytes.to_bits());
    assert_eq!(clean.safe_mode_epochs, 0);
}

/// PINNED exclusion at the policy level, over the whole fig5 set: with a
/// deterministic subset of pages pinned, every plan any policy emits
/// passes `validate_against` (which rejects pinned references), the
/// engine sees zero pinned drops, and the pinned pages end the run in
/// the tier they started in.
#[test]
fn policies_never_plan_pinned_pages_and_pinned_pages_never_move() {
    let mut cfg = MachineConfig::paper_machine();
    cfg.page_bytes = 1024;
    cfg.migrate_page_overhead = 1e-6;
    let hp = HyPlacerConfig::default();
    let total: u32 = 256;
    for pname in FIG5_POLICIES {
        let mut policy = policies::by_name(pname, &cfg, &hp).unwrap();
        let mut pt = PageTable::new(total, 1024, 64 * 1024, 512 * 1024);
        for page in 0..total {
            let want = policy.place_new(page, &pt);
            assert!(pt.allocate(page, want) || pt.allocate(page, want.other()));
        }
        // every 7th page is pinned — including pages the touch pattern
        // below keeps hot, so promotion-eligible pinned pages exist
        let pinned: Vec<u32> = (0..total).filter(|p| p % 7 == 0).collect();
        for &p in &pinned {
            pt.set_pinned(p);
        }
        let home: Vec<Tier> = pinned.iter().map(|&p| pt.flags(p).tier()).collect();
        let mut eng = MigrationEngine::new(1.0);
        for epoch in 0..12u32 {
            for i in 0..64u32 {
                let page = (i * 3 + epoch * 11) % total;
                pt.touch(page, (i + epoch) % 3 == 0);
                if i % 4 == 0 {
                    pt.touch_window(page, false);
                }
            }
            let plan = {
                let mut ctx = PolicyCtx {
                    pt: &mut pt,
                    pcmon: PcmonSnapshot::default(),
                    cfg: &cfg,
                    epoch,
                    epoch_secs: 1.0,
                    backpressure: eng.backpressure(),
                    tenants: &[],
                };
                policy.epoch_tick(&mut ctx)
            };
            plan.validate_against(&pt)
                .unwrap_or_else(|e| panic!("{pname} epoch {epoch}: planned a pinned page: {e}"));
            let sub = eng.submit(&mut pt, &plan, epoch);
            assert_eq!(sub.dropped_pinned, 0, "{pname} epoch {epoch}: pinned reference");
            let _ = eng.run_epoch(&mut pt, &cfg, epoch, 1.0);
        }
        for (&p, &t) in pinned.iter().zip(&home) {
            assert_eq!(pt.flags(p).tier(), t, "{pname}: pinned page {p} moved");
        }
    }
}

/// Satellite: run-level stat conservation. Under random fault plans ×
/// random fig5 policies × random throttles, the epoch records must
/// account for every accepted page-move: executed + stale + skipped +
/// over_quota + failed + still-queued, up to the per-reference exchange
/// residual (a valid partner of a dropped side is released unaccounted,
/// by design), with `retried` bounded by the per-entry retry cap.
#[test]
fn run_level_stats_conserve_under_random_fault_plans_and_policies() {
    check("run-level conservation", 8, |rng| {
        let pname = FIG5_POLICIES[rng.next_below(FIG5_POLICIES.len() as u64) as usize];
        let workload = ["cg-S", "cg-M", "mg-M"][rng.next_below(3) as usize];
        let epochs = 8 + rng.next_below(6) as u32;
        let mut plan = FaultPlan::none();
        if rng.chance(0.8) {
            plan.copy_fail = rng.next_f64() * 0.6;
        }
        if rng.chance(0.5) {
            plan.pin = rng.next_f64() * 0.02;
        }
        if rng.chance(0.5) {
            plan.scan_gap = rng.next_f64() * 0.3;
        }
        if rng.chance(0.7) {
            let start = rng.next_below(epochs as u64 / 2) as u32;
            let end = start + 1 + rng.next_below(epochs as u64 / 2) as u32;
            let factor = 0.25 + rng.next_f64() * 0.75;
            plan.brownouts.push(Brownout { start, end, factor });
        }
        let cfg = MachineConfig::paper_machine();
        let mut sim = SimConfig::default();
        sim.epochs = epochs;
        sim.warmup_epochs = 2;
        sim.seed = rng.next_u64();
        sim.migrate_share = if rng.chance(0.5) { 1.0 } else { 0.05 };
        sim.faults = plan;
        let hp = HyPlacerConfig::default();
        let w = workloads::by_name(workload, cfg.page_bytes, sim.epoch_secs).unwrap();
        let p = policies::by_name(pname, &cfg, &hp).unwrap();
        let r = run_pair(&cfg, &sim, w, p, 0.05);

        let (mut sub, mut exec, mut stale, mut skip, mut oq, mut fail, mut retr) =
            (0u64, 0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
        for e in &r.stats.epochs {
            sub += e.migrate_submitted;
            exec += e.migrated_pages;
            stale += e.migrate_stale;
            skip += e.migrate_skipped;
            oq += e.migrate_over_quota;
            fail += e.migrate_failed;
            retr += e.migrate_retried;
        }
        let queued_end = r.stats.epochs.last().map_or(0, |e| e.migrate_queued);
        let accounted = exec + stale + skip + oq + fail + queued_end;
        hyplacer::prop_assert!(
            accounted <= sub && sub - accounted <= stale + skip,
            "{pname}/{workload}: {sub} accepted vs {accounted} accounted \
             ({exec} exec + {stale} stale + {skip} skip + {oq} oq + {fail} fail \
             + {queued_end} queued)"
        );
        hyplacer::prop_assert!(
            retr <= sub * u64::from(faults::RETRY_MAX),
            "{pname}/{workload}: {retr} retries exceed the aggregate cap for {sub} accepted"
        );
        Ok(())
    });
}
