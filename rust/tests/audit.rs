//! Integration tests for `hyplacer audit`: golden fixtures per rule
//! (violating / allowed / clean trees), span accuracy, baseline-doc
//! counts, and the tree-wide gate that committed `rust/src` stays
//! audit-clean.

use std::path::{Path, PathBuf};

use hyplacer::analysis::{self, Severity};
use hyplacer::bench_harness::baseline;

fn fixture(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/audit").join(sub)
}

fn rendered(out: &analysis::AuditOutcome) -> Vec<String> {
    out.findings.iter().map(|f| f.render()).collect()
}

#[test]
fn violations_fixture_trips_every_rule_with_exact_spans() {
    let out = analysis::run(&fixture("violations")).expect("fixture scan");
    let got: Vec<(String, u32, u32, &str)> =
        out.findings.iter().map(|f| (f.file.clone(), f.line, f.col, f.rule)).collect();
    let want: Vec<(String, u32, u32, &str)> = [
        ("d3.rs", 2, 23, "D3"),
        ("policies/d2.rs", 1, 16, "D2"),
        ("policies/d2.rs", 3, 19, "D2"),
        ("policies/d2.rs", 4, 5, "D2"),
        ("sim/d1.rs", 1, 23, "D1"),
        ("sim/d1.rs", 3, 19, "D1"),
        ("sim/d1.rs", 4, 5, "D1"),
        ("vm/bad_allow.rs", 1, 1, "AA"),
        ("vm/bad_allow.rs", 3, 10, "N1"),
        ("vm/n1.rs", 2, 10, "N1"),
        ("vm/r1.rs", 2, 27, "R1"),
        ("vm/r1.rs", 4, 9, "R1"),
    ]
    .into_iter()
    .map(|(f, l, c, r)| (f.to_string(), l, c, r))
    .collect();
    assert_eq!(got, want);
    assert_eq!(out.errors, 12);
    assert_eq!(out.warnings, 0);
    assert!(out.findings.iter().all(|f| f.severity == Severity::Error));
}

#[test]
fn findings_render_in_editor_span_form() {
    let out = analysis::run(&fixture("violations")).expect("fixture scan");
    assert_eq!(
        out.findings[9].render(),
        "vm/n1.rs:2:10: error [N1] truncating cast `as u32` on page-index arithmetic"
    );
}

#[test]
fn allowed_fixture_is_clean_including_warnings() {
    let out = analysis::run(&fixture("allowed")).expect("fixture scan");
    assert!(out.findings.is_empty(), "{:?}", rendered(&out));
}

#[test]
fn clean_fixture_has_no_findings() {
    let out = analysis::run(&fixture("clean")).expect("fixture scan");
    assert!(out.findings.is_empty(), "{:?}", rendered(&out));
}

#[test]
fn baseline_doc_counts_per_rule() {
    let out = analysis::run(&fixture("violations")).expect("fixture scan");
    let doc = analysis::to_baseline_doc(&out);
    assert_eq!(doc.bench, "audit");
    assert_eq!(doc.metrics["findings/errors"].value, 12.0);
    assert_eq!(doc.metrics["rule/D1"].value, 3.0);
    assert_eq!(doc.metrics["rule/D2"].value, 3.0);
    assert_eq!(doc.metrics["rule/D3"].value, 1.0);
    assert_eq!(doc.metrics["rule/R1"].value, 2.0);
    assert_eq!(doc.metrics["rule/N1"].value, 3.0);
    assert_eq!(doc.metrics["rule/AA"].value, 1.0);
    assert_eq!(doc.notes.len(), 12);
}

#[test]
fn audit_baseline_gates_new_violations() {
    let clean = analysis::to_baseline_doc(&analysis::run(&fixture("clean")).expect("scan"));
    let dirty = analysis::to_baseline_doc(&analysis::run(&fixture("violations")).expect("scan"));
    assert!(baseline::compare(&clean, &clean, 0.0).is_empty());
    let fails = baseline::compare(&clean, &dirty, 0.0);
    assert!(!fails.is_empty(), "a violating tree must fail the zero baseline");
}

#[test]
fn committed_tree_is_audit_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let out = analysis::run(&root).expect("tree scan");
    let r = rendered(&out);
    assert_eq!(out.errors, 0, "audit errors in rust/src: {r:?}");
    assert_eq!(out.warnings, 0, "unused allows in rust/src: {r:?}");
}
