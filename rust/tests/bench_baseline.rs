//! Integration: the perf-baseline pipeline end-to-end — `hyplacer bench
//! --json DIR` emitting `BENCH_*.json`, `hyplacer bench-check` passing
//! against the committed repo baselines, and failing on a baseline
//! inflated beyond tolerance.

use std::path::Path;
use std::process::Command;

use hyplacer::bench_harness::baseline::{BaselineDoc, MetricKind};

fn exe() -> &'static str {
    env!("CARGO_BIN_EXE_hyplacer")
}

/// Path of a committed repo-root baseline (tests run inside rust/).
fn committed(name: &str) -> String {
    format!("{}/../{}", env!("CARGO_MANIFEST_DIR"), name)
}

fn fresh_docs(dir: &Path) {
    std::fs::create_dir_all(dir).unwrap();
    let out = Command::new(exe())
        .args(["bench", "--quick", "--json", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn bench_emits_docs_and_check_passes_against_committed_baselines() {
    let dir = std::env::temp_dir().join("hyplacer_bench_emit_test");
    fresh_docs(&dir);
    for name in ["BENCH_hotpath.json", "BENCH_sweep.json"] {
        assert!(dir.join(name).exists(), "{name} not emitted");
        // emitted docs parse back through the baseline model
        let doc = BaselineDoc::load(dir.join(name).to_str().unwrap()).unwrap();
        assert_eq!(doc.mode, "quick");
        assert!(doc.compared_len() > 0, "{name} has no gating metrics");
    }
    // the committed baselines gate cleanly against a fresh smoke run
    let baselines = format!(
        "{},{}",
        committed("BENCH_hotpath.json"),
        committed("BENCH_sweep.json")
    );
    let out = Command::new(exe())
        .args([
            "bench-check",
            "--baseline",
            &baselines,
            "--current",
            dir.to_str().unwrap(),
            "--tolerance",
            "0.25",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench-check failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.matches(": OK").count(), 2, "{stdout}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_check_fails_on_baseline_inflated_beyond_tolerance() {
    let dir = std::env::temp_dir().join("hyplacer_bench_tamper_test");
    fresh_docs(&dir);
    // inflate one ratio metric of the fresh sweep doc by 2x and use that
    // as the "baseline": the comparator must reject it
    let fresh =
        BaselineDoc::load(dir.join("BENCH_sweep.json").to_str().unwrap()).unwrap();
    let mut tampered = fresh.clone();
    let v = tampered.metrics["app_gb_per_epoch/cg-S"].value;
    tampered.put("app_gb_per_epoch/cg-S", v * 2.0, MetricKind::Ratio);
    let tampered_path = dir.join("TAMPERED_sweep.json");
    tampered.save(tampered_path.to_str().unwrap()).unwrap();

    let out = Command::new(exe())
        .args([
            "bench-check",
            "--baseline",
            tampered_path.to_str().unwrap(),
            "--current",
            dir.to_str().unwrap(),
            "--tolerance",
            "0.25",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "inflated baseline must fail the gate");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("app_gb_per_epoch/cg-S"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_check_recomputes_live_without_current_dir() {
    let out = Command::new(exe())
        .args(["bench-check", "--baseline", &committed("BENCH_hotpath.json")])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}
