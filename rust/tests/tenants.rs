//! Multi-tenant co-run subsystem: the cross-layer guarantees.
//!
//! * **1-tenant equivalence** — a 1-tenant `MultiSimulation` is
//!   bit-identical to the legacy `Simulation` for every fig5 policy,
//!   pinned in lockstep per epoch. This is the contract that keeps all
//!   pre-tenant checkpoints and BENCH baselines valid.
//! * **Bijective offset mapping** — property test over random
//!   footprints: no page owned by two tenants, every tenant page
//!   resolvable back to its tenant.
//! * **Determinism** — a 2-tenant mix is bit-identical across `--jobs`
//!   values, and resumes from its own checkpoint with 0 executed cells
//!   and a byte-identical artifact.
//! * **Contention demo** — the committed `configs/mix_demo.toml`
//!   scenario (`hyplacer run -w 'is.M+pr.M' --config
//!   configs/mix_demo.toml`): HyPlacer beats ADM-default on aggregate
//!   weighted speedup (common solo-reference normalization).

#![allow(clippy::field_reassign_with_default)]

use hyplacer::bench_harness::fig_mix;
use hyplacer::config::{parse::Doc, HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::Simulation;
use hyplacer::exec::SweepSpec;
use hyplacer::policies::{self, FIG5_POLICIES};
use hyplacer::prop_assert;
use hyplacer::tenants::{
    run_mix, run_mix_with_solos, MixSpec, MultiSimulation, TenantSet, TenantSpec,
};
use hyplacer::util::proptest;
use hyplacer::workloads;

#[test]
fn one_tenant_multisim_is_bit_identical_to_legacy_for_fig5_policies() {
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 14;
    sim.warmup_epochs = 3;
    let hp = HyPlacerConfig::default();
    for pname in FIG5_POLICIES {
        let w = workloads::by_name("cg-M", cfg.page_bytes, sim.epoch_secs).unwrap();
        let p_legacy = policies::by_name(pname, &cfg, &hp).unwrap();
        let p_multi = policies::by_name(pname, &cfg, &hp).unwrap();
        let mut legacy = Simulation::new(cfg.clone(), sim.clone(), w, p_legacy, 0.05);
        let mix = MixSpec::single("cg-M");
        let mut multi =
            MultiSimulation::new(cfg.clone(), sim.clone(), &mix, p_multi, 0.05).unwrap();
        // lockstep: every epoch's wall clock must agree to the bit
        for e in 0..sim.epochs {
            let a = legacy.step();
            let b = multi.step();
            assert_eq!(a.to_bits(), b.to_bits(), "{pname}: epoch {e} wall diverged");
        }
        // both hot-path instruments agree (same RNG stream, same walks)
        assert_eq!(legacy.rng_draws(), multi.rng_draws(), "{pname}: rng draws");
        assert_eq!(legacy.pte_visits(), multi.pte_visits(), "{pname}: pte visits");
        let ra = legacy.finish();
        let rb = multi.finish();
        assert_eq!(ra.workload, rb.workload, "{pname}");
        assert_eq!(ra.policy, rb.policy, "{pname}");
        assert_eq!(ra.total_wall_secs.to_bits(), rb.total_wall_secs.to_bits(), "{pname}");
        assert_eq!(ra.total_app_bytes.to_bits(), rb.total_app_bytes.to_bits(), "{pname}");
        assert_eq!(ra.throughput.to_bits(), rb.throughput.to_bits(), "{pname}");
        assert_eq!(
            ra.steady_throughput.to_bits(),
            rb.steady_throughput.to_bits(),
            "{pname}"
        );
        assert_eq!(
            ra.energy_j_per_byte.to_bits(),
            rb.energy_j_per_byte.to_bits(),
            "{pname}"
        );
        assert_eq!(ra.total_energy_j.to_bits(), rb.total_energy_j.to_bits(), "{pname}");
        assert_eq!(ra.migrated_pages, rb.migrated_pages, "{pname}");
        assert_eq!(
            ra.dram_traffic_share.to_bits(),
            rb.dram_traffic_share.to_bits(),
            "{pname}"
        );
        assert_eq!(ra.migrate_queue_peak, rb.migrate_queue_peak, "{pname}");
        assert_eq!(
            ra.migrate_deferred_ratio.to_bits(),
            rb.migrate_deferred_ratio.to_bits(),
            "{pname}"
        );
        assert_eq!(
            ra.migrate_stale_ratio.to_bits(),
            rb.migrate_stale_ratio.to_bits(),
            "{pname}"
        );
        // the multi run additionally carries the 1 tenant's summary
        assert!(ra.tenants.is_empty());
        assert_eq!(rb.tenants.len(), 1);
        assert_eq!(rb.tenants[0].name, "CG-M");
    }
}

#[test]
fn one_tenant_equivalence_holds_under_throttled_migration() {
    // the engine's carry-over queue is global state: pin equivalence in
    // the throttled regime too (share 0.05 defers work across epochs)
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 12;
    sim.warmup_epochs = 2;
    sim.migrate_share = 0.05;
    let hp = HyPlacerConfig::default();
    let w = workloads::by_name("cg-L", cfg.page_bytes, sim.epoch_secs).unwrap();
    let mut legacy = Simulation::new(
        cfg.clone(),
        sim.clone(),
        w,
        policies::by_name("hyplacer", &cfg, &hp).unwrap(),
        0.05,
    );
    let mut multi = MultiSimulation::new(
        cfg.clone(),
        sim.clone(),
        &MixSpec::single("cg-L"),
        policies::by_name("hyplacer", &cfg, &hp).unwrap(),
        0.05,
    )
    .unwrap();
    for e in 0..sim.epochs {
        let a = legacy.step();
        let b = multi.step();
        assert_eq!(a.to_bits(), b.to_bits(), "epoch {e} wall diverged");
    }
    let ra = legacy.finish();
    let rb = multi.finish();
    assert!(ra.migrate_queue_peak > 0, "throttle did not engage");
    assert_eq!(ra.migrate_queue_peak, rb.migrate_queue_peak);
    assert_eq!(ra.migrated_pages, rb.migrated_pages);
}

#[test]
fn tenant_offset_mapping_is_bijective_under_random_footprints() {
    proptest::check("tenant-bijection", 200, |rng| {
        let n = 1 + rng.next_below(6) as usize;
        let mut fps: Vec<u32> = Vec::with_capacity(n);
        for _ in 0..n {
            fps.push(1 + rng.next_below(5000) as u32);
        }
        let specs: Vec<TenantSpec> =
            (0..n).map(|i| TenantSpec::new(&format!("t{i}"))).collect();
        let set = TenantSet::from_footprints(specs, &fps)?;
        let total: u64 = fps.iter().map(|&f| f as u64).sum();
        prop_assert!(
            set.total_pages() as u64 == total,
            "address space {} != sum of footprints {total}",
            set.total_pages()
        );
        // every tenant page resolves to a unique global page and back
        for idx in 0..n {
            let samples = [0, fps[idx] - 1, rng.next_below(fps[idx] as u64) as u32];
            for &local in &samples {
                let g = set
                    .to_global(idx, local)
                    .ok_or_else(|| format!("tenant {idx} local {local} unmappable"))?;
                prop_assert!(
                    set.tenant_of(g) == Some(idx),
                    "page {g}: owner {:?} != tenant {idx}",
                    set.tenant_of(g)
                );
                prop_assert!(
                    set.to_local(g) == Some((idx, local)),
                    "page {g} does not round-trip to ({idx}, {local})"
                );
            }
            prop_assert!(
                set.to_global(idx, fps[idx]).is_none(),
                "tenant {idx}: past-end local page resolved"
            );
        }
        // every global page has exactly one owner whose range holds it
        for _ in 0..32 {
            let g = rng.next_below(total + 8) as u32;
            let owners: Vec<usize> = (0..n)
                .filter(|&j| g >= set.base(j) && g < set.base(j) + set.pages(j))
                .collect();
            match set.tenant_of(g) {
                Some(i) => prop_assert!(
                    owners == vec![i],
                    "page {g}: tenant_of = {i}, range owners = {owners:?}"
                ),
                None => prop_assert!(
                    owners.is_empty() && g as u64 >= total,
                    "page {g} unowned inside the address space"
                ),
            }
        }
        Ok(())
    });
}

fn mix_spec_for_jobs_test() -> SweepSpec {
    let mut sim = SimConfig::default();
    sim.epochs = 8;
    sim.warmup_epochs = 2;
    let mut spec =
        SweepSpec::new(MachineConfig::paper_machine(), sim, HyPlacerConfig::default());
    spec.workloads = vec!["cg.S+mg.S".to_string()];
    spec.policies = vec!["adm-default".to_string(), "hyplacer".to_string()];
    spec.seeds = vec![42, 7];
    spec
}

#[test]
fn two_tenant_mix_is_bit_identical_across_jobs() {
    let spec = mix_spec_for_jobs_test();
    let serial = spec.run(1).unwrap();
    let par = spec.run(4).unwrap();
    assert_eq!(serial.results.len(), 4);
    for (a, b) in serial.results.iter().zip(par.results.iter()) {
        assert_eq!(a.key, b.key);
        assert_eq!(a.sim.workload, "CG-S+MG-S");
        assert_eq!(
            a.sim.total_wall_secs.to_bits(),
            b.sim.total_wall_secs.to_bits(),
            "{}/{}",
            a.policy,
            a.seed
        );
        assert_eq!(a.sim.migrated_pages, b.sim.migrated_pages);
    }
    assert_eq!(serial.to_json().render(), par.to_json().render());
}

#[test]
fn mix_cells_resume_with_zero_executed_and_byte_identical_json() {
    let spec = mix_spec_for_jobs_test();
    let first = spec.run_with_cache(2, None).unwrap();
    assert_eq!(first.executed, 4);
    // resume via a JSON round trip (what --out/--resume does across
    // processes): 0 executed cells, byte-identical rendering
    let rendered = first.run.to_json().render();
    let prior = hyplacer::exec::SweepRun::from_json(
        &hyplacer::report::json::parse(&rendered).unwrap(),
    )
    .unwrap();
    let resumed = spec.run_with_cache(1, Some(&prior)).unwrap();
    assert_eq!(resumed.executed, 0);
    assert_eq!(resumed.cached, 4);
    assert_eq!(resumed.run.to_json().render(), rendered);
}

/// Load the committed contention-demo config (what `hyplacer run -w
/// 'is.M+pr.M' --config configs/mix_demo.toml` uses).
fn mix_demo_config() -> (MachineConfig, SimConfig, HyPlacerConfig) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../configs/mix_demo.toml");
    let text = std::fs::read_to_string(path).expect("committed configs/mix_demo.toml");
    let doc = Doc::parse(&text).expect("mix_demo.toml parses");
    let mut machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    let mut hp = HyPlacerConfig::default();
    machine.apply_doc(&doc);
    sim.apply_doc(&doc);
    hp.apply_doc(&doc);
    (machine, sim, hp)
}

#[test]
fn hyplacer_beats_adm_default_on_mix_weighted_speedup() {
    // The acceptance demo: a write-heavy NPB tenant (IS-M) co-run with
    // a graph tenant (PR-M). Aggregate weighted speedup uses the
    // scheduling-literature normalization — per-tenant co-run
    // throughput over a COMMON solo reference (the adm-default solo
    // runs) — so policies are compared on the same scale.
    let (machine, sim, hp) = mix_demo_config();
    let mix = MixSpec::parse("is.M+pr.M").unwrap();
    let wf = hp.delay_secs / sim.epoch_secs;
    let adm = run_mix_with_solos(&machine, &sim, &mix, wf, || {
        policies::by_name("adm-default", &machine, &hp).unwrap()
    })
    .unwrap();
    let hyp_corun = run_mix(
        &machine,
        &sim,
        &mix,
        policies::by_name("hyplacer", &machine, &hp).unwrap(),
        wf,
    )
    .unwrap();
    let weighted = |corun: &hyplacer::coordinator::SimResult| -> f64 {
        let mut sum = 0.0;
        let mut wsum = 0.0;
        for (t, solo) in corun.tenants.iter().zip(adm.solos.iter()) {
            sum += t.share_weight * (t.steady_throughput / solo.steady_throughput);
            wsum += t.share_weight;
        }
        sum / wsum
    };
    let ws_adm = weighted(&adm.corun);
    let ws_hyp = weighted(&hyp_corun);
    assert!(
        ws_hyp > ws_adm,
        "hyplacer weighted speedup {ws_hyp:.3} must beat adm-default {ws_adm:.3}"
    );
    // sanity on the fairness metrics the mix run reports
    assert_eq!(adm.slowdowns.len(), 2);
    assert!(adm.unfairness >= 1.0 - 1e-9);
    // under first-touch the first tenant grabs DRAM; the second is
    // stranded in PM — the contention pathology the subsystem opens up
    let first = &adm.corun.tenants[0];
    let second = &adm.corun.tenants[1];
    assert!(
        first.mean_dram_share > second.mean_dram_share,
        "first-touch should strand the late-allocated tenant: {} vs {}",
        first.mean_dram_share,
        second.mean_dram_share
    );
}

#[test]
fn hyplacer_qos_without_quotas_is_bit_identical_to_stock() {
    // The QoS variant's no-quota contract: on a mix that sets no hard
    // caps or soft shares, "hyplacer-qos" must execute the exact stock
    // HyPlacer sequence — pinned in lockstep per epoch plus on both
    // hot-path instruments. This is what lets the variant ship without
    // re-keying any checkpoint or baseline.
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 14;
    sim.warmup_epochs = 3;
    let hp = HyPlacerConfig::default();
    let mix = MixSpec::parse("cg.S+mg.S").unwrap();
    assert!(!mix.has_quotas());
    let mut stock = MultiSimulation::new(
        cfg.clone(),
        sim.clone(),
        &mix,
        policies::by_name("hyplacer", &cfg, &hp).unwrap(),
        0.05,
    )
    .unwrap();
    let mut qos = MultiSimulation::new(
        cfg.clone(),
        sim.clone(),
        &mix,
        policies::by_name("hyplacer-qos", &cfg, &hp).unwrap(),
        0.05,
    )
    .unwrap();
    for e in 0..sim.epochs {
        let a = stock.step();
        let b = qos.step();
        assert_eq!(a.to_bits(), b.to_bits(), "epoch {e} wall diverged");
    }
    assert_eq!(stock.rng_draws(), qos.rng_draws(), "rng draws");
    assert_eq!(stock.pte_visits(), qos.pte_visits(), "pte visits");
    let ra = stock.finish();
    let rb = qos.finish();
    assert_eq!(ra.policy, "hyplacer");
    assert_eq!(rb.policy, "hyplacer-qos");
    assert_eq!(ra.total_wall_secs.to_bits(), rb.total_wall_secs.to_bits());
    assert_eq!(ra.total_app_bytes.to_bits(), rb.total_app_bytes.to_bits());
    assert_eq!(ra.steady_throughput.to_bits(), rb.steady_throughput.to_bits());
    assert_eq!(ra.total_energy_j.to_bits(), rb.total_energy_j.to_bits());
    assert_eq!(ra.migrated_pages, rb.migrated_pages);
}

#[test]
fn no_epoch_ends_with_a_tenant_above_its_hard_cap() {
    // Property: whatever the policy plans, the engine-enforced hard cap
    // is an invariant at every epoch boundary, not just at the end of
    // the run. Random caps, random policy, random epoch counts.
    use hyplacer::config::Tier;
    use hyplacer::vm::PlaneQuery;
    let policies_under_test = ["adm-default", "hyplacer", "hyplacer-qos"];
    proptest::check("hard-cap-invariant", 12, |rng| {
        let cfg = MachineConfig::paper_machine();
        let mut sim = SimConfig::default();
        sim.epochs = 6 + rng.next_below(6) as u32;
        sim.warmup_epochs = 2;
        let hp = HyPlacerConfig::default();
        let cap_a = 1 + rng.next_below(4000) as u32;
        let cap_b = 1 + rng.next_below(4000) as u32;
        let spec = if rng.chance(0.5) {
            format!("cg.S:{cap_a}+mg.S:{cap_b}")
        } else {
            format!("cg.S:{cap_a}/2+mg.S")
        };
        let mix = MixSpec::parse(&spec).map_err(|e| format!("{spec}: {e}"))?;
        let pname = policies_under_test[rng.next_below(3) as usize];
        let policy = policies::by_name(pname, &cfg, &hp)
            .ok_or_else(|| format!("unknown policy {pname}"))?;
        let mut m = MultiSimulation::new(cfg.clone(), sim.clone(), &mix, policy, 0.05)
            .map_err(|e| format!("{spec}: {e}"))?;
        for e in 0..sim.epochs {
            m.step();
            let set = m.tenant_set();
            let pt = m.page_table();
            for ti in 0..set.len() {
                if let Some(cap) = set.spec(ti).hard_cap_pages {
                    let used = pt.count_matching_in(
                        set.base(ti),
                        set.base(ti) + set.pages(ti),
                        PlaneQuery::tier(Tier::Dram),
                    );
                    prop_assert!(
                        used <= u64::from(cap),
                        "{spec} under {pname}: tenant {ti} holds {used} DRAM \
                         pages over cap {cap} after epoch {e}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn qos_quotas_improve_unfairness_on_the_antagonist_mix() {
    // The committed antagonist demo (also the 4th fig-mix default):
    // write-heavy IS-M thrashes latency-sensitive PR-M on the demo
    // machine. Stock HyPlacer happily feeds the writer — its SWITCH
    // mode pulls write-intensive IS pages into DRAM on merit, so PR
    // eats the contention. Capping IS at 5000 of the 16384 DRAM pages
    // and giving PR the larger soft share hands the freed DRAM to PR:
    // hyplacer-qos must improve unfairness over the uncapped stock run
    // without losing aggregate weighted speedup (PR carries weight 2 in
    // both mixes, so the metrics are compared on the same scale).
    let (machine, sim, hp) = mix_demo_config();
    let wf = hp.delay_secs / sim.epoch_secs;
    let stock_mix = MixSpec::parse("is.M+pr.M*2").unwrap();
    let stock = run_mix_with_solos(&machine, &sim, &stock_mix, wf, || {
        policies::by_name("hyplacer", &machine, &hp).unwrap()
    })
    .unwrap();
    let qos_mix = MixSpec::parse(fig_mix::ANTAGONIST_MIX).unwrap();
    assert!(qos_mix.has_quotas());
    let qos = run_mix_with_solos(&machine, &sim, &qos_mix, wf, || {
        policies::by_name("hyplacer-qos", &machine, &hp).unwrap()
    })
    .unwrap();
    assert!(
        qos.unfairness < stock.unfairness,
        "quotas must improve unfairness: qos {:.3} vs stock {:.3} \
         (slowdowns qos {:?} stock {:?})",
        qos.unfairness,
        stock.unfairness,
        qos.slowdowns,
        stock.slowdowns
    );
    assert!(
        qos.weighted_speedup >= stock.weighted_speedup,
        "quotas must not cost weighted speedup: qos {:.3} vs stock {:.3}",
        qos.weighted_speedup,
        stock.weighted_speedup
    );
    // and the isolation pressure is visible: the capped writer had
    // promotions rejected at the quota wall
    assert!(
        qos.corun.stats.migrate_over_quota_total() > 0,
        "the antagonist demo should actually exercise the cap"
    );
}

#[test]
fn cli_run_accepts_a_mix() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let out = std::process::Command::new(exe)
        .args(["run", "-w", "cg.S+mg.S", "--epochs", "24"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("CG-S+MG-S"), "{stdout}");
    assert!(stdout.contains("weighted speedup"), "{stdout}");
    assert!(stdout.contains("slowdown"), "{stdout}");
}

#[test]
fn cli_fig_mix_smoke_and_resume() {
    let exe = env!("CARGO_BIN_EXE_hyplacer");
    let dir = std::env::temp_dir();
    let out_path = dir.join("hyplacer_fig_mix_smoke.json");
    let out_path = out_path.to_str().unwrap();
    std::fs::remove_file(out_path).ok();
    let run = |resume: bool| {
        let mut args = vec![
            "fig-mix",
            "-w",
            "cg.S+mg.S",
            "--epochs",
            "6",
            "--jobs",
            "2",
            "--out",
            out_path,
        ];
        if resume {
            args.push("--resume");
        }
        std::process::Command::new(exe).args(&args).output().unwrap()
    };
    let first = run(false);
    assert!(first.status.success(), "{}", String::from_utf8_lossy(&first.stderr));
    let stdout = String::from_utf8_lossy(&first.stdout);
    assert!(
        stdout.contains("fig-mix: executed 6 of 6 cells (0 cached)"),
        "{stdout}"
    );
    let bytes_first = std::fs::read(out_path).unwrap();
    let second = run(true);
    assert!(second.status.success(), "{}", String::from_utf8_lossy(&second.stderr));
    let stdout = String::from_utf8_lossy(&second.stdout);
    assert!(
        stdout.contains("fig-mix: executed 0 of 6 cells (6 cached)"),
        "{stdout}"
    );
    let bytes_second = std::fs::read(out_path).unwrap();
    assert_eq!(bytes_first, bytes_second, "resume rewrite must be byte-identical");
    std::fs::remove_file(out_path).ok();
}
