//! Run tracing (DESIGN.md §15): the observer-effect-zero contract and
//! the per-page provenance reconstruction.
//!
//! * **Observer effect zero** — a run with a full in-memory tracer
//!   attached (including per-page provenance over every page) produces
//!   a `SimResult` bit-identical to the untraced run, for every fig5
//!   policy and for a faulted multi-tenant antagonist mix. This is the
//!   contract that keeps `--trace` out of sweep cell keys.
//! * **Stream invariants** — the emitted JSONL carries the versioned
//!   envelope, a strictly increasing `seq`, nondecreasing epochs, and
//!   never a wall-clock value.
//! * **Provenance** — a sampled page's lifecycle reconstructs
//!   submit → defer → execute under a throttled engine, and
//!   submit → retry → execute under copy-fault injection.
//! * **Conversion** — the committed fixture converts to a valid Chrome
//!   trace-event document and a stable text summary.

#![allow(clippy::field_reassign_with_default)]

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::{run_pair, run_pair_traced, SimResult};
use hyplacer::faults::FaultPlan;
use hyplacer::policies::{self, FIG5_POLICIES};
use hyplacer::report::json;
use hyplacer::tenants::{self, MixSpec};
use hyplacer::trace::{chrome, MemSink, Tracer};
use hyplacer::workloads;

/// Assert every result field matches bit for bit (floats via to_bits).
fn assert_bit_identical(a: &SimResult, b: &SimResult, label: &str) {
    assert_eq!(a.total_wall_secs.to_bits(), b.total_wall_secs.to_bits(), "{label}: wall");
    assert_eq!(a.total_app_bytes.to_bits(), b.total_app_bytes.to_bits(), "{label}: bytes");
    assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{label}: throughput");
    assert_eq!(
        a.steady_throughput.to_bits(),
        b.steady_throughput.to_bits(),
        "{label}: steady"
    );
    assert_eq!(
        a.energy_j_per_byte.to_bits(),
        b.energy_j_per_byte.to_bits(),
        "{label}: energy/B"
    );
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits(), "{label}: energy");
    assert_eq!(a.migrated_pages, b.migrated_pages, "{label}: migrated");
    assert_eq!(
        a.dram_traffic_share.to_bits(),
        b.dram_traffic_share.to_bits(),
        "{label}: dram share"
    );
    assert_eq!(a.migrate_queue_peak, b.migrate_queue_peak, "{label}: queue peak");
    assert_eq!(
        a.migrate_deferred_ratio.to_bits(),
        b.migrate_deferred_ratio.to_bits(),
        "{label}: deferred"
    );
    assert_eq!(
        a.migrate_stale_ratio.to_bits(),
        b.migrate_stale_ratio.to_bits(),
        "{label}: stale"
    );
    assert_eq!(a.migrate_retried, b.migrate_retried, "{label}: retried");
    assert_eq!(a.migrate_failed, b.migrate_failed, "{label}: failed");
    assert_eq!(a.safe_mode_epochs, b.safe_mode_epochs, "{label}: safe-mode");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{label}: tenant count");
    for (ta, tb) in a.tenants.iter().zip(b.tenants.iter()) {
        assert_eq!(ta.name, tb.name, "{label}: tenant name");
        assert_eq!(ta.app_bytes.to_bits(), tb.app_bytes.to_bits(), "{label}: tenant bytes");
    }
}

/// A tracer that records everything in memory, sampling all pages.
fn full_tracer() -> Tracer {
    Tracer::new(Box::new(MemSink::new())).with_pages(vec![(0, u64::MAX)])
}

/// Run the tracer's sink dry and return the rendered JSONL lines.
fn lines_of(tracer: Tracer) -> Vec<String> {
    let sink = tracer.into_sink();
    sink.lines().expect("MemSink exposes lines").to_vec()
}

#[test]
fn tracing_has_zero_observer_effect_for_fig5_policies() {
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 10;
    sim.warmup_epochs = 2;
    // throttle the engine so deferrals (and their extra events) flow
    sim.migrate_share = 0.05;
    let hp = HyPlacerConfig::default();
    for pname in FIG5_POLICIES {
        let w_a = workloads::by_name("cg-S", cfg.page_bytes, sim.epoch_secs).unwrap();
        let w_b = workloads::by_name("cg-S", cfg.page_bytes, sim.epoch_secs).unwrap();
        let p_a = policies::by_name(pname, &cfg, &hp).unwrap();
        let p_b = policies::by_name(pname, &cfg, &hp).unwrap();
        let plain = run_pair(&cfg, &sim, w_a, p_a, 0.05);
        let (traced, tracer) = run_pair_traced(&cfg, &sim, w_b, p_b, 0.05, Some(full_tracer()));
        assert_bit_identical(&plain, &traced, pname);
        let tracer = tracer.expect("tracer comes back out");
        assert!(tracer.written() > 0, "{pname}: no events emitted");
        assert_eq!(tracer.dropped(), 0, "{pname}: in-memory sink never drops");
    }
}

#[test]
fn tracing_has_zero_observer_effect_on_a_faulted_antagonist_mix() {
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 10;
    sim.warmup_epochs = 2;
    sim.faults = FaultPlan::parse("copy:0.05,pin:0.001,brownout:ep2..6*0.5,scan-gap:0.05")
        .unwrap();
    let mix = MixSpec::parse("is.M:5000/1+pr.M*2/2").unwrap();
    for pname in ["hyplacer", "hyplacer-qos", "adm-default"] {
        let hp = HyPlacerConfig::default();
        let p_a = policies::by_name(pname, &cfg, &hp).unwrap();
        let p_b = policies::by_name(pname, &cfg, &hp).unwrap();
        let plain = tenants::run_mix(&cfg, &sim, &mix, p_a, 0.05).unwrap();
        let (traced, tracer) =
            tenants::run_mix_traced(&cfg, &sim, &mix, p_b, 0.05, Some(full_tracer())).unwrap();
        assert_bit_identical(&plain, &traced, pname);
        assert!(tracer.unwrap().written() > 0, "{pname}: no events emitted");
    }
}

#[test]
fn stream_is_versioned_ordered_and_wall_clock_free() {
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 8;
    sim.warmup_epochs = 2;
    sim.faults = FaultPlan::parse("brownout:ep2..5*0.5,scan-gap:0.2").unwrap();
    let mix = MixSpec::parse("is.M:5000/1+pr.M*2/2").unwrap();
    let hp = HyPlacerConfig::default();
    let p = policies::by_name("hyplacer-qos", &cfg, &hp).unwrap();
    let (_, tracer) =
        tenants::run_mix_traced(&cfg, &sim, &mix, p, 0.05, Some(full_tracer())).unwrap();
    let lines = lines_of(tracer.unwrap());
    assert!(!lines.is_empty());

    let mut last_seq: Option<f64> = None;
    let mut last_epoch = 0.0f64;
    // the simulated clock: 0 at bind, advanced by each epoch's wall secs
    let mut expected_t = 0.0f64;
    let mut kinds = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let doc = json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}"));
        assert_eq!(doc.get("v").and_then(|v| v.as_f64()), Some(1.0), "line {i}: v");
        let seq = doc.get("seq").and_then(|v| v.as_f64()).expect("seq");
        if let Some(prev) = last_seq {
            assert!(seq > prev, "line {i}: seq not strictly increasing");
        }
        last_seq = Some(seq);
        let epoch = doc.get("epoch").and_then(|v| v.as_f64()).expect("epoch");
        assert!(epoch >= last_epoch, "line {i}: epoch ran backwards");
        last_epoch = epoch;
        // the stamp is simulated time: exactly the sum of the wall secs
        // of the epochs already completed — never a host clock
        let t = doc.get("t").and_then(|v| v.as_f64()).expect("t");
        assert_eq!(
            t.to_bits(),
            expected_t.to_bits(),
            "line {i}: t is not the simulated clock"
        );
        let kind = doc.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string();
        if kind == "epoch_end" {
            expected_t += doc.get("wall_secs").and_then(|v| v.as_f64()).expect("wall_secs");
        }
        kinds.push(kind);
    }
    assert_eq!(kinds[0], "header", "stream starts with the run preamble");
    for k in ["epoch_begin", "shard_task", "policy_tick", "migrate_exec", "tenant_epoch",
              "epoch_end", "fault_arm", "page"] {
        assert!(kinds.iter().any(|x| x == k), "missing kind {k}");
    }
    // 8 epochs → 8 epoch frames in this segment
    assert_eq!(kinds.iter().filter(|k| *k == "epoch_end").count(), 8);
}

/// Collect each sampled page's lifecycle (kind == "page" events, in
/// emission order) from rendered JSONL lines.
fn lifecycles(lines: &[String]) -> std::collections::BTreeMap<u64, Vec<String>> {
    let mut map = std::collections::BTreeMap::new();
    for line in lines {
        let doc = json::parse(line).unwrap();
        if doc.get("kind").and_then(|k| k.as_str()) != Some("page") {
            continue;
        }
        let page = doc.get("page").and_then(|v| v.as_f64()).unwrap() as u64;
        let step = doc.get("step").and_then(|s| s.as_str()).unwrap().to_string();
        map.entry(page).or_insert_with(Vec::new).push(step);
    }
    map
}

/// True if `steps` contains `pattern` as a subsequence, where the final
/// element may match any of the executed-move steps.
fn has_subsequence(steps: &[String], pattern: &[&str]) -> bool {
    let mut i = 0;
    for s in steps {
        let want = pattern[i];
        let hit = if want == "<exec>" {
            matches!(s.as_str(), "promote" | "demote" | "exchange")
        } else {
            s == want
        };
        if hit {
            i += 1;
            if i == pattern.len() {
                return true;
            }
        }
    }
    false
}

#[test]
fn provenance_reconstructs_submit_defer_execute_under_throttling() {
    // 5% migrate share on cg-L backs the queue up (the throttle cell
    // the engine's own budget test pins): some sampled page must be
    // submitted, sit deferred past at least one epoch boundary, and
    // then actually move
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 16;
    sim.warmup_epochs = 2;
    sim.migrate_share = 0.05;
    let hp = HyPlacerConfig::default();
    let w = workloads::by_name("cg-L", cfg.page_bytes, sim.epoch_secs).unwrap();
    let p = policies::by_name("hyplacer", &cfg, &hp).unwrap();
    let (r, tracer) = run_pair_traced(&cfg, &sim, w, p, 0.05, Some(full_tracer()));
    assert!(r.migrate_deferred_ratio > 0.0, "throttled run must defer");
    let lines = lines_of(tracer.unwrap());
    let by_page = lifecycles(&lines);
    assert!(!by_page.is_empty(), "no page events");
    let full = by_page
        .values()
        .filter(|steps| has_subsequence(steps, &["submit", "defer", "<exec>"]))
        .count();
    assert!(full > 0, "no page shows submit -> defer -> execute");
}

#[test]
fn provenance_reconstructs_submit_retry_execute_under_copy_faults() {
    // 60% copy-failure probability: transient failures re-queue moves
    // (retry) and most re-attempts eventually land
    let cfg = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 12;
    sim.warmup_epochs = 3;
    sim.faults = FaultPlan::parse("copy:0.6").unwrap();
    let hp = HyPlacerConfig::default();
    let w = workloads::by_name("cg-M", cfg.page_bytes, sim.epoch_secs).unwrap();
    let p = policies::by_name("hyplacer", &cfg, &hp).unwrap();
    let (r, tracer) = run_pair_traced(&cfg, &sim, w, p, 0.05, Some(full_tracer()));
    assert!(r.migrate_retried > 0, "fault plan must force retries");
    let lines = lines_of(tracer.unwrap());
    let by_page = lifecycles(&lines);
    let retried = by_page
        .values()
        .filter(|steps| has_subsequence(steps, &["submit", "retry", "<exec>"]))
        .count();
    assert!(retried > 0, "no page shows submit -> retry -> execute");
}

#[test]
fn committed_fixture_converts_to_chrome_and_summary() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/trace/sample.jsonl");
    let text = std::fs::read_to_string(path).expect("committed fixture");

    let doc = chrome::to_chrome(&text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    // the converted document round-trips through the JSON parser
    let reparsed = json::parse(&doc.render()).unwrap();
    assert!(reparsed.get("traceEvents").is_some());
    // the two headers split the fixture into two processes
    let pids: std::collections::BTreeSet<u64> = events
        .iter()
        .filter_map(|e| e.get("pid").and_then(|p| p.as_f64()))
        .map(|p| p as u64)
        .collect();
    assert_eq!(pids.len(), 2, "one pid per run segment");
    // epoch slices, counters and instants all present
    assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")));
    assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C")));
    assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i")));

    let text = chrome::summary(&text).unwrap();
    assert!(text.contains("trace summary: 21 events, 2 segment(s)"), "{text}");
    assert!(text.contains("segment 1: hyplacer @ cg-M (seed 42)"), "{text}");
    assert!(text.contains("segment 2: memm @ cg-M (seed 42)"), "{text}");
    assert!(text.contains("promotions: 1  demotions: 1  exchanges: 0"), "{text}");
    assert!(text.contains("retried: 0  failed: 0  over-quota: 2"), "{text}");
    assert!(text.contains("safe-mode epochs: 1"), "{text}");
    assert!(text.contains("queue depth peak: 1 at epoch 0"), "{text}");
    assert!(text.contains("top churning pages: 0x20 (3 steps)"), "{text}");
}
