//! Cross-module property tests (in-tree proptest helper): conservation,
//! capacity and determinism invariants of the coordinator/policy/vm
//! stack under randomized workloads and policies.


#![allow(clippy::field_reassign_with_default)]
use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig, Tier, GB, GIB};
use hyplacer::coordinator::Simulation;
use hyplacer::policies;
use hyplacer::util::proptest::check;
use hyplacer::util::Rng64;
use hyplacer::workloads::mlc::Mlc;
use hyplacer::workloads::Workload;

const POLICIES: [&str; 7] =
    ["adm-default", "memm", "autonuma", "memos", "nimble", "hyplacer", "partitioned"];

fn random_machine(rng: &mut Rng64) -> MachineConfig {
    let mut m = MachineConfig::paper_machine();
    // shrink the machine so tests are fast but ratios stay realistic
    m.page_bytes = 2 * 1024 * 1024;
    m.dram.capacity = (1 + rng.next_below(8)) * GIB;
    m.pm.capacity = (8 + rng.next_below(32)) * GIB;
    m
}

fn random_workload(rng: &mut Rng64, m: &MachineConfig) -> Box<dyn Workload> {
    let total_pages = (m.dram.capacity + m.pm.capacity) / m.page_bytes;
    let active = 1 + rng.next_below(total_pages.min(4000)) as u32;
    let inactive = rng.next_below(1 + total_pages.saturating_sub(active as u64) / 2) as u32;
    Box::new(Mlc::new(
        active,
        inactive,
        (1.0 + rng.next_f64() * 40.0) * GB,
        rng.next_f64() * 0.5,
        rng.next_f64(),
        1.0,
    ))
}

#[test]
fn pages_conserved_and_capacity_respected_across_all_policies() {
    check("conservation", 40, |rng| {
        let m = random_machine(rng);
        let w = random_workload(rng, &m);
        let footprint = w.footprint_pages() as u64;
        if footprint > m.dram.capacity / m.page_bytes + m.pm.capacity / m.page_bytes {
            return Ok(()); // cannot map; allocation would (rightly) panic
        }
        let pname = POLICIES[rng.next_below(POLICIES.len() as u64) as usize];
        let policy = policies::by_name(pname, &m, &HyPlacerConfig::default()).unwrap();
        let mut sim_cfg = SimConfig::default();
        sim_cfg.epochs = 6;
        sim_cfg.seed = rng.next_u64();
        let mut sim = Simulation::new(m.clone(), sim_cfg, w, policy, 0.05);
        for e in 0..6 {
            let wall = sim.step();
            if !(wall.is_finite() && wall >= 0.0) {
                return Err(format!("{pname}: epoch {e} wall={wall}"));
            }
            let pt = sim.page_table();
            pt.check_index_consistent()
                .map_err(|err| format!("{pname}: epoch {e}: activity index: {err}"))?;
            let (dram, pm) = pt.recount();
            if dram + pm != footprint {
                return Err(format!(
                    "{pname}: epoch {e}: {dram}+{pm} pages != footprint {footprint}"
                ));
            }
            if dram != pt.used_pages(Tier::Dram) || pm != pt.used_pages(Tier::Pm) {
                return Err(format!("{pname}: incremental counters drifted"));
            }
            if dram > pt.capacity_pages(Tier::Dram) || pm > pt.capacity_pages(Tier::Pm) {
                return Err(format!("{pname}: capacity exceeded ({dram}, {pm})"));
            }
        }
        Ok(())
    });
}

#[test]
fn runs_are_deterministic_per_seed() {
    check("determinism", 10, |rng| {
        let m = random_machine(rng);
        let seed = rng.next_u64();
        let pname = POLICIES[rng.next_below(POLICIES.len() as u64) as usize];
        let mut run = || {
            let w = {
                let mut r2 = Rng64::new(seed);
                random_workload(&mut r2, &m)
            };
            let policy = policies::by_name(pname, &m, &HyPlacerConfig::default()).unwrap();
            let mut sim_cfg = SimConfig::default();
            sim_cfg.epochs = 5;
            sim_cfg.seed = seed;
            let w_pages = w.footprint_pages() as u64;
            if w_pages > m.dram.capacity / m.page_bytes + m.pm.capacity / m.page_bytes {
                return None;
            }
            Some(Simulation::new(m.clone(), sim_cfg, w, policy, 0.05).run())
        };
        match (run(), run()) {
            (Some(a), Some(b)) => {
                if a.total_wall_secs.to_bits() != b.total_wall_secs.to_bits() {
                    return Err(format!(
                        "{pname}: {} vs {}",
                        a.total_wall_secs, b.total_wall_secs
                    ));
                }
                if a.migrated_pages != b.migrated_pages {
                    return Err(format!("{pname}: migrations diverged"));
                }
                Ok(())
            }
            _ => Ok(()),
        }
    });
}

#[test]
fn perfmodel_service_time_monotone_under_random_demands() {
    use hyplacer::mem::{EpochDemand, PerfModel, TierDemand};
    let model = PerfModel::new(&MachineConfig::paper_machine());
    check("service-monotone", 200, |rng| {
        let mk = |rng: &mut Rng64| TierDemand {
            read_bytes: rng.next_f64() * 40.0 * GB,
            write_bytes: rng.next_f64() * 20.0 * GB,
            random_frac: rng.next_f64(),
        };
        let mut d = EpochDemand::default();
        d.dram = mk(rng);
        d.pm = mk(rng);
        d.app_bytes = d.dram.total() + d.pm.total();
        let t0 = model.service(&d).wall_secs;
        // adding bytes to either tier never speeds the epoch up...
        // but NOTE: adding *read* bytes can raise the harmonic-mix
        // ceiling, so monotonicity is asserted for proportional growth.
        let mut bigger = d;
        bigger.dram.read_bytes *= 1.3;
        bigger.dram.write_bytes *= 1.3;
        bigger.pm.read_bytes *= 1.3;
        bigger.pm.write_bytes *= 1.3;
        bigger.app_bytes *= 1.3;
        let t1 = model.service(&bigger).wall_secs;
        if t1 + 1e-12 < t0 {
            return Err(format!("scaling demand 1.3x reduced time: {t0} -> {t1}"));
        }
        Ok(())
    });
}

#[test]
fn closed_loop_throughput_bounded_by_ceilings() {
    use hyplacer::mem::PerfModel;
    let m = MachineConfig::paper_machine();
    let model = PerfModel::new(&m);
    check("closed-loop-bounds", 100, |rng| {
        let threads = 1 + rng.next_below(32) as u32;
        let wf = rng.next_f64() * 0.5;
        let rf = rng.next_f64();
        let share = rng.next_f64();
        let tp = model.closed_loop_throughput(threads, wf, rf, share);
        if !(tp.is_finite() && tp > 0.0) {
            return Err(format!("tp={tp}"));
        }
        let sum_peaks = m.dram.peak_read_bw() + m.pm.peak_read_bw();
        if tp > sum_peaks {
            return Err(format!("tp {tp} above aggregate nominal peak {sum_peaks}"));
        }
        Ok(())
    });
}
