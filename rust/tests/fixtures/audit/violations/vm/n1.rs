pub fn fold(page: u64) -> u32 {
    page as u32
}
