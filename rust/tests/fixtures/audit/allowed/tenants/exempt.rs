pub fn id(x: u32) -> u32 {
    x
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_and_casts_are_fine_in_tests() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap() as u8, 3);
    }
}
