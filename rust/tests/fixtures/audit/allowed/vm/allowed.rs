pub fn fold(page: u64) -> u32 {
    page as u32 // audit-allow(N1): bounded by the table's u32 page count
}

pub fn fold_above(page: u64) -> u32 {
    // audit-allow(N1): bounded by the table's u32 page count
    page as u32
}
