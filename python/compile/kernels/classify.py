"""L1 Pallas kernel: fused per-page classification + migration scoring.

This is the vectorized analogue of HyPlacer's SelMo PTE callback (paper
Sec. 4.4): for every resident page it folds the freshly sampled R/D bits
into exponentially decayed hotness / write-intensity estimates, classifies
the page (cold / read-intensive / write-intensive), and emits per-mode
migration priority scores that the rust Control loop turns into PageFind
responses via top-k selection.

The kernel is a single fused pass over the page-stats arrays: one HBM->VMEM
round trip per block, all math elementwise in fp32 on the VPU. Block shape
is an (8,128)-multiple so the same BlockSpec lowers to TPU tiles untouched;
on this image it runs under ``interpret=True`` (CPU) — real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

Inputs (all f32[N], N a multiple of BLOCK):
  ref      -- accessed-bit sample for the window (0/1, or a count if the
              caller accumulated multiple walks)
  dirty    -- dirty-bit sample for the window (0/1 or count)
  hot_ewma -- previous hotness EWMA
  wr_ewma  -- previous write-intensity EWMA
  tier     -- 0.0 = DRAM, 1.0 = DCPMM
  valid    -- 1.0 if the slot holds a resident page else 0.0
  params   -- f32[8] broadcast parameter vector, see PARAM_* below

Outputs (f32[N] each):
  new_hot       -- updated hotness EWMA
  new_wr        -- updated write-intensity EWMA
  page_class    -- 0 cold, 1 read-intensive, 2 write-intensive
  demote_score  -- DEMOTE priority (DRAM pages; colder => higher)
  promote_score -- PROMOTE / PROMOTE_INT / SWITCH priority (DCPMM pages;
                   hotter and more write-dominated => higher)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter-vector layout (kept in sync with rust/src/policies/hyplacer/native.rs
# and runtime/placement.rs -- change in lockstep).
PARAM_ALPHA = 0        # EWMA decay factor for the fresh sample
PARAM_HOT_THRESH = 1   # hotness EWMA above which a page is "intensive"
PARAM_WR_THRESH = 2    # write EWMA above which an intensive page is write-bound
PARAM_WR_WEIGHT = 3    # weight of write intensity in promotion scores
PARAM_COLD_BIAS = 4    # extra demotion priority for never-referenced pages
PARAM_AGE_WEIGHT = 5   # weight of staleness (1 - hot) in demotion score
PARAM_RESERVED6 = 6
PARAM_RESERVED7 = 7
N_PARAMS = 8

# 512*128 fp32 lanes per block: 6 inputs + 5 outputs = 11 arrays
# * 64 KiB/array = 0.69 MiB of VMEM per grid step -- far below the
# 16 MiB budget, leaving room for double buffering.
BLOCK = 512 * 128

CLASS_COLD = 0.0
CLASS_READ = 1.0
CLASS_WRITE = 2.0


def _classify_block(
    ref_ref,
    dirty_ref,
    hot_ref,
    wr_ref,
    tier_ref,
    valid_ref,
    params_ref,
    new_hot_ref,
    new_wr_ref,
    class_ref,
    demote_ref,
    promote_ref,
):
    """Kernel body: one VMEM-resident block of page stats."""
    ref = ref_ref[...]
    dirty = dirty_ref[...]
    hot = hot_ref[...]
    wr = wr_ref[...]
    tier = tier_ref[...]
    valid = valid_ref[...]

    alpha = params_ref[PARAM_ALPHA]
    hot_thresh = params_ref[PARAM_HOT_THRESH]
    wr_thresh = params_ref[PARAM_WR_THRESH]
    wr_weight = params_ref[PARAM_WR_WEIGHT]
    cold_bias = params_ref[PARAM_COLD_BIAS]
    age_weight = params_ref[PARAM_AGE_WEIGHT]

    # A dirty bit implies an access even if the walker raced the R-bit clear.
    touched = jnp.maximum(ref, dirty)

    # EWMA fold of the fresh window sample (saturate the sample at 1.0 so a
    # multi-walk accumulation cannot blow past the [0,1] hotness range).
    new_hot = alpha * jnp.minimum(touched, 1.0) + (1.0 - alpha) * hot
    new_wr = alpha * jnp.minimum(dirty, 1.0) + (1.0 - alpha) * wr

    is_hot = new_hot > hot_thresh
    is_write = jnp.logical_and(is_hot, new_wr > wr_thresh)
    page_class = jnp.where(
        is_write, CLASS_WRITE, jnp.where(is_hot, CLASS_READ, CLASS_COLD)
    )

    in_dram = tier < 0.5
    in_pm = jnp.logical_not(in_dram)

    # DEMOTE: pick the coldest, most read-dominated DRAM pages first.
    # Staleness dominates; among equally-stale pages prefer read-dominated
    # victims (Observation 2: keep write-intensive pages in DRAM).
    never = jnp.logical_and(touched < 0.5, new_hot <= hot_thresh)
    demote = (
        age_weight * (1.0 - new_hot)
        + (1.0 - age_weight) * (1.0 - new_wr)
        + jnp.where(never, cold_bias, 0.0)
    )
    demote_score = jnp.where(jnp.logical_and(in_dram, valid > 0.5), demote, -1.0)

    # PROMOTE family: hotter + more write-dominated DCPMM pages first.
    promote = new_hot + wr_weight * new_wr
    promote_score = jnp.where(jnp.logical_and(in_pm, valid > 0.5), promote, -1.0)

    invalid = valid < 0.5
    new_hot_ref[...] = jnp.where(invalid, 0.0, new_hot)
    new_wr_ref[...] = jnp.where(invalid, 0.0, new_wr)
    class_ref[...] = jnp.where(invalid, CLASS_COLD, page_class)
    demote_ref[...] = demote_score
    promote_ref[...] = promote_score


@functools.partial(jax.jit, static_argnames=("block",))
def classify_pages(ref, dirty, hot_ewma, wr_ewma, tier, valid, params, *, block=BLOCK):
    """Run the fused classification kernel over N pages.

    All array arguments are f32[N] with N a multiple of ``block``;
    ``params`` is f32[N_PARAMS]. Returns the 5-tuple of outputs described
    in the module docstring.
    """
    n = ref.shape[0]
    if n % block != 0:
        raise ValueError(f"page array length {n} not a multiple of block {block}")
    grid = (n // block,)
    stats_spec = pl.BlockSpec((block,), lambda i: (i,))
    param_spec = pl.BlockSpec((N_PARAMS,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 5
    return pl.pallas_call(
        _classify_block,
        grid=grid,
        in_specs=[stats_spec] * 6 + [param_spec],
        out_specs=[stats_spec] * 5,
        out_shape=out_shape,
        interpret=True,
    )(ref, dirty, hot_ewma, wr_ewma, tier, valid, params)
