"""Pure-jnp oracle for the L1 classification kernel.

Mirrors kernels/classify.py semantics exactly, but with no pallas — plain
jnp over the whole array. pytest/hypothesis assert allclose between the two
on swept shapes, dtypes and parameter points; the rust native fallback
(policies/hyplacer/native.rs) is unit-tested against vectors generated from
this oracle (see tests/test_golden.py + rust golden tests).
"""

from __future__ import annotations

import jax.numpy as jnp

from .classify import (
    CLASS_COLD,
    CLASS_READ,
    CLASS_WRITE,
    PARAM_AGE_WEIGHT,
    PARAM_ALPHA,
    PARAM_COLD_BIAS,
    PARAM_HOT_THRESH,
    PARAM_WR_THRESH,
    PARAM_WR_WEIGHT,
)


def classify_pages_ref(ref, dirty, hot_ewma, wr_ewma, tier, valid, params):
    """Reference implementation; same signature/returns as classify_pages."""
    alpha = params[PARAM_ALPHA]
    hot_thresh = params[PARAM_HOT_THRESH]
    wr_thresh = params[PARAM_WR_THRESH]
    wr_weight = params[PARAM_WR_WEIGHT]
    cold_bias = params[PARAM_COLD_BIAS]
    age_weight = params[PARAM_AGE_WEIGHT]

    touched = jnp.maximum(ref, dirty)
    new_hot = alpha * jnp.minimum(touched, 1.0) + (1.0 - alpha) * hot_ewma
    new_wr = alpha * jnp.minimum(dirty, 1.0) + (1.0 - alpha) * wr_ewma

    is_hot = new_hot > hot_thresh
    is_write = jnp.logical_and(is_hot, new_wr > wr_thresh)
    page_class = jnp.where(
        is_write, CLASS_WRITE, jnp.where(is_hot, CLASS_READ, CLASS_COLD)
    )

    in_dram = tier < 0.5
    in_pm = jnp.logical_not(in_dram)
    never = jnp.logical_and(touched < 0.5, new_hot <= hot_thresh)
    demote = (
        age_weight * (1.0 - new_hot)
        + (1.0 - age_weight) * (1.0 - new_wr)
        + jnp.where(never, cold_bias, 0.0)
    )
    demote_score = jnp.where(jnp.logical_and(in_dram, valid > 0.5), demote, -1.0)
    promote = new_hot + wr_weight * new_wr
    promote_score = jnp.where(jnp.logical_and(in_pm, valid > 0.5), promote, -1.0)

    invalid = valid < 0.5
    return (
        jnp.where(invalid, 0.0, new_hot),
        jnp.where(invalid, 0.0, new_wr),
        jnp.where(invalid, CLASS_COLD, page_class),
        demote_score,
        promote_score,
    )
