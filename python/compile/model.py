"""L2: HyPlacer's placement decision model as a JAX compute graph.

Two jitted entry points, both AOT-lowered to HLO text by ``aot.py`` and
executed from the rust Control hot loop via PJRT (python is never on the
request path):

``placement_step``
    The per-epoch page pass. Calls the L1 pallas kernel
    (kernels/classify.py) to fold the sampled R/D bits into hotness /
    write-intensity EWMAs, classify every page and score migration
    candidates — then reduces the per-page outputs into the small
    aggregate vector Control needs for its threshold decisions
    (per-tier, per-class page counts and intensity sums). Fusing the
    reduction into the same HLO module saves rust a second pass over
    the page arrays.

``plan_cost``
    The decision-lookahead cost model. Given K candidate demand splits
    (read/write bytes per tier after a hypothetical migration batch),
    predict each candidate's epoch service time under a simplified
    DRAM+DCPMM performance surface (read/write-asymmetric bandwidth
    ceilings + latency floor — the same shape the rust simulator
    implements in full). Control uses argmin over candidates to size
    SWITCH/PROMOTE batches.

Aggregate vector layout (f32[N_AGGREGATES]), kept in sync with
rust/src/runtime/placement.rs:
  0 dram_valid   1 pm_valid
  2 dram_cold    3 dram_read   4 dram_write
  5 pm_cold      6 pm_read     7 pm_write
  8 dram_hot_sum 9 pm_hot_sum 10 dram_wr_sum 11 pm_wr_sum
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.classify import BLOCK, CLASS_READ, CLASS_WRITE, classify_pages

N_AGGREGATES = 12

# plan_cost tier-parameter vector layout (f32[N_COST_PARAMS]); values are
# produced by rust from its calibrated MachineConfig (mem/perfmodel.rs).
COST_DRAM_READ_BW = 0    # bytes/s peak
COST_DRAM_WRITE_BW = 1
COST_PM_READ_BW = 2
COST_PM_WRITE_BW = 3
COST_DRAM_LAT = 4        # seconds, idle load-to-use
COST_PM_READ_LAT = 5
COST_PM_WRITE_LAT = 6
COST_LINE_BYTES = 7      # access granularity (cache line)
COST_OVERLAP = 8         # 0..1, cross-tier overlap factor (1 = perfect)
COST_RESERVED9 = 9
N_COST_PARAMS = 10


def placement_step(ref, dirty, hot_ewma, wr_ewma, tier, valid, params, *, block=BLOCK):
    """Full per-epoch pass: L1 kernel + aggregate reduction.

    Returns (new_hot, new_wr, page_class, demote_score, promote_score,
    aggregates) where aggregates is f32[N_AGGREGATES].
    """
    new_hot, new_wr, page_class, demote_score, promote_score = classify_pages(
        ref, dirty, hot_ewma, wr_ewma, tier, valid, params, block=block
    )
    ok = valid > 0.5
    in_dram = jnp.logical_and(ok, tier < 0.5)
    in_pm = jnp.logical_and(ok, tier >= 0.5)
    is_read = page_class == CLASS_READ
    is_write = page_class == CLASS_WRITE
    is_cold = page_class < 0.5

    def msum(mask, arr=None):
        a = jnp.ones_like(new_hot) if arr is None else arr
        return jnp.sum(jnp.where(mask, a, 0.0))

    aggregates = jnp.stack(
        [
            msum(in_dram),
            msum(in_pm),
            msum(jnp.logical_and(in_dram, is_cold)),
            msum(jnp.logical_and(in_dram, is_read)),
            msum(jnp.logical_and(in_dram, is_write)),
            msum(jnp.logical_and(in_pm, is_cold)),
            msum(jnp.logical_and(in_pm, is_read)),
            msum(jnp.logical_and(in_pm, is_write)),
            msum(in_dram, new_hot),
            msum(in_pm, new_hot),
            msum(in_dram, new_wr),
            msum(in_pm, new_wr),
        ]
    )
    return new_hot, new_wr, page_class, demote_score, promote_score, aggregates


def _tier_time(read_bytes, write_bytes, read_bw, write_bw, read_lat, write_lat, line):
    """Service time for one tier under a read/write byte demand.

    Bandwidth term: reads and writes share the channel, so the effective
    ceiling is the mix-weighted harmonic combination of the read and
    write ceilings (this is what collapses DCPMM throughput as the write
    fraction grows — Observation 2). Latency floor: per-line base cost
    for demand too sparse to be bandwidth-bound.
    """
    eps = 1e-9
    tiny = 1e-30
    total = read_bytes + write_bytes
    rfrac = read_bytes / (total + eps)
    wfrac = 1.0 - rfrac
    eff_bw = 1.0 / (rfrac / (read_bw + eps) + wfrac / (write_bw + eps) + tiny)
    bw_time = total / (eff_bw + eps)
    lines = total / jnp.maximum(line, 1.0)
    base_lat = rfrac * read_lat + wfrac * write_lat
    # ~64 lines in flight per tier (32 HW threads x 2 outstanding misses):
    # the latency floor only binds when demand is too sparse for the
    # bandwidth term to matter.
    lat_time = lines * base_lat / 64.0
    return jnp.maximum(bw_time, lat_time)


def plan_cost(demands, cost_params):
    """Predict epoch service time for K candidate demand splits.

    demands: f32[K, 4] — (dram_read_bytes, dram_write_bytes,
                          pm_read_bytes, pm_write_bytes) per candidate.
    cost_params: f32[N_COST_PARAMS].
    Returns f32[K] predicted seconds.
    """
    line = cost_params[COST_LINE_BYTES]
    overlap = cost_params[COST_OVERLAP]
    t_dram = _tier_time(
        demands[:, 0],
        demands[:, 1],
        cost_params[COST_DRAM_READ_BW],
        cost_params[COST_DRAM_WRITE_BW],
        cost_params[COST_DRAM_LAT],
        cost_params[COST_DRAM_LAT],
        line,
    )
    t_pm = _tier_time(
        demands[:, 2],
        demands[:, 3],
        cost_params[COST_PM_READ_BW],
        cost_params[COST_PM_WRITE_BW],
        cost_params[COST_PM_READ_LAT],
        cost_params[COST_PM_WRITE_LAT],
        line,
    )
    # overlap=1: tiers served fully in parallel (max); overlap=0: serial (sum).
    return overlap * jnp.maximum(t_dram, t_pm) + (1.0 - overlap) * (t_dram + t_pm)


def placement_step_fn(n, block=None):
    """placement_step specialized to n pages (pallas block <= n)."""
    blk = block or min(BLOCK, n)
    if n % blk != 0:
        raise ValueError(f"n={n} not a multiple of block={blk}")

    def fn(ref, dirty, hot_ewma, wr_ewma, tier, valid, params):
        return placement_step(
            ref, dirty, hot_ewma, wr_ewma, tier, valid, params, block=blk
        )

    return fn
