"""AOT pipeline: lower the L2 placement model to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime
(rust/src/runtime/) loads the text with ``HloModuleProto::from_text_file``,
compiles it on the PJRT CPU client and executes it on the request path.

HLO TEXT is the interchange format, NOT ``.serialize()`` /
``jax.export``-style serialized protos: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (all f32):
  placement_<N>.hlo.txt  — placement_step over N pages, for each capacity
                           bucket N in BUCKETS. rust picks the smallest
                           bucket >= resident page count and pads.
  plan_cost_<K>.hlo.txt  — plan_cost over K candidate plans.
  manifest.json          — bucket list + parameter-layout versions, so the
                           rust side can sanity-check at load time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.classify import N_PARAMS
from .model import N_COST_PARAMS, placement_step_fn, plan_cost

# Capacity buckets for the per-page pass. 8192 serves tests/small examples;
# 65536/262144 cover the evaluation runs (2 MiB sim pages -> 262144 pages
# models a 512 GiB address-space footprint, larger than any workload here).
BUCKETS = (8192, 65536, 262144)
PLAN_K = 32

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_placement(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    pspec = jax.ShapeDtypeStruct((N_PARAMS,), jnp.float32)
    fn = placement_step_fn(n)
    lowered = jax.jit(fn).lower(spec, spec, spec, spec, spec, spec, pspec)
    return to_hlo_text(lowered)


def lower_plan_cost(k: int) -> str:
    dspec = jax.ShapeDtypeStruct((k, 4), jnp.float32)
    pspec = jax.ShapeDtypeStruct((N_COST_PARAMS,), jnp.float32)
    lowered = jax.jit(plan_cost).lower(dspec, pspec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--out",
        default=None,
        help="also write the first placement bucket to this exact path "
        "(Makefile stamp target)",
    )
    ap.add_argument("--buckets", type=int, nargs="*", default=list(BUCKETS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "version": MANIFEST_VERSION,
        "n_params": N_PARAMS,
        "n_cost_params": N_COST_PARAMS,
        "plan_k": PLAN_K,
        "placement_buckets": [],
    }

    first_text = None
    for n in args.buckets:
        text = lower_placement(n)
        path = os.path.join(args.out_dir, f"placement_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["placement_buckets"].append(n)
        if first_text is None:
            first_text = text
        print(f"wrote {path} ({len(text)} chars)")

    text = lower_plan_cost(PLAN_K)
    path = os.path.join(args.out_dir, f"plan_cost_{PLAN_K}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(first_text)
        print(f"wrote {args.out} (stamp)")


if __name__ == "__main__":
    main()
