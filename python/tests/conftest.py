# Graceful degradation for environments without jax/pallas (e.g. the
# rust-only CI runner): the kernel/model/aot/golden test modules import
# jax at module scope, so they must be skipped at *collection* time —
# otherwise pytest dies on ImportError before any skip marker runs.
# test_bench_baselines.py is stdlib-only and always collected, so the
# suite never reports "no tests ran".
import importlib.util

if importlib.util.find_spec("jax") is None:
    collect_ignore = [
        "test_aot.py",
        "test_golden.py",
        "test_kernel.py",
        "test_model.py",
    ]
