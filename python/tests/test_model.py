# pytest: L2 placement model — aggregate reduction + plan_cost surface.
from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.classify import CLASS_COLD, CLASS_READ, CLASS_WRITE
from compile.kernels.ref import classify_pages_ref
from compile.model import (
    COST_DRAM_LAT,
    COST_DRAM_READ_BW,
    COST_DRAM_WRITE_BW,
    COST_LINE_BYTES,
    COST_OVERLAP,
    COST_PM_READ_BW,
    COST_PM_READ_LAT,
    COST_PM_WRITE_BW,
    COST_PM_WRITE_LAT,
    N_AGGREGATES,
    N_COST_PARAMS,
    placement_step_fn,
    plan_cost,
)
from .test_kernel import mk_params, mk_stats

GB = 1e9


def paper_cost_params(overlap=1.0):
    """Cost params mirroring the paper machine (2 DRAM + 2 DCPMM channels)."""
    p = np.zeros(N_COST_PARAMS, dtype=np.float32)
    p[COST_DRAM_READ_BW] = 34 * GB
    p[COST_DRAM_WRITE_BW] = 28 * GB
    p[COST_PM_READ_BW] = 13.2 * GB
    p[COST_PM_WRITE_BW] = 4.6 * GB
    p[COST_DRAM_LAT] = 81e-9
    p[COST_PM_READ_LAT] = 169e-9
    p[COST_PM_WRITE_LAT] = 94e-9
    p[COST_LINE_BYTES] = 64.0
    p[COST_OVERLAP] = overlap
    return jnp.asarray(p)


# ----- placement_step aggregates -----


def np_aggregates(stats, params):
    """Independent numpy recomputation of the aggregate vector."""
    new_hot, new_wr, cls, _, _ = [np.asarray(a) for a in classify_pages_ref(*stats, params)]
    tier = np.asarray(stats[4])
    valid = np.asarray(stats[5]) > 0.5
    dram = valid & (tier < 0.5)
    pm = valid & (tier >= 0.5)
    agg = np.array(
        [
            dram.sum(),
            pm.sum(),
            (dram & (cls == CLASS_COLD)).sum(),
            (dram & (cls == CLASS_READ)).sum(),
            (dram & (cls == CLASS_WRITE)).sum(),
            (pm & (cls == CLASS_COLD)).sum(),
            (pm & (cls == CLASS_READ)).sum(),
            (pm & (cls == CLASS_WRITE)).sum(),
            new_hot[dram].sum(),
            new_hot[pm].sum(),
            new_wr[dram].sum(),
            new_wr[pm].sum(),
        ],
        dtype=np.float64,
    )
    return agg


@pytest.mark.parametrize("n", [256, 2048])
def test_aggregates_match_numpy(n):
    stats = mk_stats(n, seed=n + 1)
    params = mk_params()
    out = placement_step_fn(n)(*stats, params)
    agg = np.asarray(out[-1], dtype=np.float64)
    expected = np_aggregates(stats, params)
    assert agg.shape == (N_AGGREGATES,)
    np.testing.assert_allclose(agg, expected, rtol=1e-4, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), valid_density=st.floats(0, 1))
def test_aggregate_counts_conserve(seed, valid_density):
    """Class counts per tier must sum to the tier's valid-page count."""
    n = 512
    stats = mk_stats(n, seed=seed, valid_density=valid_density)
    agg = np.asarray(placement_step_fn(n)(*stats, mk_params())[-1])
    assert agg[2] + agg[3] + agg[4] == pytest.approx(agg[0], abs=0.5)
    assert agg[5] + agg[6] + agg[7] == pytest.approx(agg[1], abs=0.5)
    valid = np.asarray(stats[5]) > 0.5
    assert agg[0] + agg[1] == pytest.approx(valid.sum(), abs=0.5)


# ----- plan_cost surface properties -----


def demands(dram_r, dram_w, pm_r, pm_w):
    return jnp.asarray(np.array([[dram_r, dram_w, pm_r, pm_w]], dtype=np.float32))


def cost1(dram_r, dram_w, pm_r, pm_w, overlap=1.0):
    return float(plan_cost(demands(dram_r, dram_w, pm_r, pm_w), paper_cost_params(overlap))[0])


def test_dram_faster_than_pm():
    """The same demand served from DRAM must be predicted cheaper."""
    assert cost1(10 * GB, 5 * GB, 0, 0) < cost1(0, 0, 10 * GB, 5 * GB)


def test_pm_write_asymmetry():
    """Writes on DCPMM must cost far more than reads (Fig. 2 asymmetry)."""
    t_reads = cost1(0, 0, 10 * GB, 0)
    t_writes = cost1(0, 0, 0, 10 * GB)
    assert t_writes > 2.0 * t_reads


def test_dram_mild_asymmetry():
    t_reads = cost1(10 * GB, 0, 0, 0)
    t_writes = cost1(0, 10 * GB, 0, 0)
    assert t_writes > t_reads
    assert t_writes < 1.5 * t_reads


def test_overlap_bounds():
    """Parallel (overlap=1) <= any mix <= serial (overlap=0)."""
    a = (6 * GB, 2 * GB, 4 * GB, 1 * GB)
    t_par = cost1(*a, overlap=1.0)
    t_half = cost1(*a, overlap=0.5)
    t_ser = cost1(*a, overlap=0.0)
    assert t_par <= t_half <= t_ser
    assert t_ser == pytest.approx(
        cost1(a[0], a[1], 0, 0) + cost1(0, 0, a[2], a[3]), rel=1e-4
    )


@settings(max_examples=30, deadline=None)
@given(
    dr=st.floats(0, 50 * GB),
    dw=st.floats(0, 50 * GB),
    pr=st.floats(0, 50 * GB),
    pw=st.floats(0, 50 * GB),
    extra=st.floats(1e6, 20 * GB),
)
def test_cost_monotone_in_demand(dr, dw, pr, pw, extra):
    """Adding bytes anywhere never reduces predicted time."""
    base = cost1(dr, dw, pr, pw)
    assert cost1(dr + extra, dw, pr, pw) >= base - 1e-9
    assert cost1(dr, dw + extra, pr, pw) >= base - 1e-9
    assert cost1(dr, dw, pr + extra, pw) >= base - 1e-9
    assert cost1(dr, dw, pr, pw + extra) >= base - 1e-9


def test_cost_batched_matches_single():
    rows = np.array(
        [
            [10 * GB, 1 * GB, 2 * GB, 0.5 * GB],
            [0, 0, 30 * GB, 0],
            [5 * GB, 5 * GB, 5 * GB, 5 * GB],
        ],
        dtype=np.float32,
    )
    batched = np.asarray(plan_cost(jnp.asarray(rows), paper_cost_params()))
    for i, row in enumerate(rows):
        single = cost1(*row)
        assert batched[i] == pytest.approx(single, rel=1e-5)


def test_zero_demand_zero_cost():
    assert cost1(0, 0, 0, 0) == pytest.approx(0.0, abs=1e-6)


def test_fill_dram_first_is_optimal_for_moderate_demand():
    """Moving a read-dominated slice of demand from PM to free DRAM must
    reduce predicted time — the geometry behind Observation 1."""
    before = cost1(0, 0, 20 * GB, 0)
    after = cost1(15 * GB, 0, 5 * GB, 0)
    assert after < before


def test_bandwidth_balance_gain_is_modest():
    """Observation 3: even all-reads, the parallel-tier gain over all-DRAM
    is bounded (DCPMM adds much less than nominal peak suggests)."""
    all_dram = cost1(60 * GB, 0, 0, 0)
    best = min(
        cost1((60 - s) * GB, 0, s * GB, 0) for s in range(0, 31, 2)
    )
    gain = all_dram / best
    assert 1.0 <= gain < 1.5
