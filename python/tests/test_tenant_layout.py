# Cross-language check of the tenant address-space mapping — stdlib
# only, so it runs even where jax/numpy are absent.
#
# The rust side owns the implementation (`TenantSet` in
# rust/src/tenants/mod.rs: `from_footprints` packs tenants at
# accumulated base offsets; `tenant_of` is a binary search over bases
# with the Ok(i)/Err(0)/Err(i-1) resolution rust's `binary_search_by`
# produces). This file is an independent port of that algorithm,
# property-tested for the bijection the multi-tenant subsystem relies
# on: every page has exactly one owner, every tenant-local page
# round-trips through the global space, and out-of-space pages resolve
# to no one. The rust property test (tests/tenants.rs) checks the same
# invariants against the real implementation; together they pin the
# algorithm from two independent codebases, mirroring the PR-4 python
# port of the migration-engine livelock argument.
from __future__ import annotations

import bisect
import random

import pytest

U32_MAX = 2**32 - 1


class TenantSet:
    """Python port of rust `tenants::TenantSet` (layout math only)."""

    def __init__(self, footprints):
        if not footprints:
            raise ValueError("empty tenant set")
        self.ranges = []
        cursor = 0
        for fp in footprints:
            if fp == 0:
                raise ValueError("zero footprint")
            self.ranges.append((cursor, fp))
            cursor += fp
            if cursor > U32_MAX:
                raise OverflowError("combined footprint overflows u32")

    def total_pages(self):
        base, pages = self.ranges[-1]
        return base + pages

    def tenant_of(self, page):
        # mirrors rust binary_search_by over bases:
        # Ok(i) -> i, Err(0) -> None, Err(i) -> i - 1
        bases = [b for b, _ in self.ranges]
        i = bisect.bisect_left(bases, page)
        if i < len(bases) and bases[i] == page:
            idx = i
        elif i == 0:
            return None
        else:
            idx = i - 1
        base, pages = self.ranges[idx]
        return idx if base <= page < base + pages else None

    def to_global(self, idx, local):
        if idx >= len(self.ranges):
            return None
        base, pages = self.ranges[idx]
        return base + local if local < pages else None

    def to_local(self, page):
        idx = self.tenant_of(page)
        if idx is None:
            return None
        return (idx, page - self.ranges[idx][0])


def test_layout_is_packed_and_contiguous():
    s = TenantSet([10, 5, 7])
    assert [b for b, _ in s.ranges] == [0, 10, 15]
    assert s.total_pages() == 22
    assert s.tenant_of(9) == 0
    assert s.tenant_of(10) == 1
    assert s.tenant_of(21) == 2
    assert s.tenant_of(22) is None
    assert s.to_global(1, 4) == 14
    assert s.to_global(1, 5) is None
    assert s.to_local(14) == (1, 4)


def test_degenerate_layouts_rejected():
    with pytest.raises(ValueError):
        TenantSet([])
    with pytest.raises(ValueError):
        TenantSet([3, 0, 2])
    with pytest.raises(OverflowError):
        TenantSet([U32_MAX, 2])


def test_bijection_property():
    rng = random.Random(0xC0FFEE)
    for case in range(500):
        n = rng.randint(1, 6)
        fps = [rng.randint(1, 5000) for _ in range(n)]
        s = TenantSet(fps)
        total = sum(fps)
        assert s.total_pages() == total
        # exhaustive on small layouts, sampled on large ones
        if total < 300:
            pages = range(total + 5)
        else:
            pages = [rng.randrange(total + 5) for _ in range(100)]
        for g in pages:
            owner = s.tenant_of(g)
            owners = [j for j, (b, p) in enumerate(s.ranges) if b <= g < b + p]
            if g < total:
                assert len(owners) == 1, f"case {case}: page {g} owners {owners}"
                assert owner == owners[0]
                idx, local = s.to_local(g)
                assert s.to_global(idx, local) == g
            else:
                assert owner is None and not owners
        for idx, fp in enumerate(fps):
            for local in {0, fp - 1, rng.randrange(fp)}:
                g = s.to_global(idx, local)
                assert s.tenant_of(g) == idx
                assert s.to_local(g) == (idx, local)
            assert s.to_global(idx, fp) is None
