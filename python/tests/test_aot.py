# AOT artifact sanity: the HLO text artifacts must exist after
# `make artifacts`, parse as HLO modules, and carry the shapes the rust
# runtime expects. Skipped (not failed) when artifacts/ has not been built
# yet so `pytest` stays runnable standalone.
from __future__ import annotations

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def art(path):
    p = os.path.join(ART, path)
    if not os.path.exists(p):
        pytest.skip(f"{path} not built (run `make artifacts`)")
    return p


def test_manifest_consistent():
    with open(art("manifest.json")) as f:
        m = json.load(f)
    from compile.kernels.classify import N_PARAMS
    from compile.model import N_COST_PARAMS

    assert m["n_params"] == N_PARAMS
    assert m["n_cost_params"] == N_COST_PARAMS
    for n in m["placement_buckets"]:
        assert os.path.exists(os.path.join(ART, f"placement_{n}.hlo.txt"))
    assert os.path.exists(os.path.join(ART, f"plan_cost_{m['plan_k']}.hlo.txt"))


@pytest.mark.parametrize("bucket", [8192, 65536, 262144])
def test_placement_hlo_mentions_shapes(bucket):
    with open(art(f"placement_{bucket}.hlo.txt")) as f:
        text = f.read()
    assert "HloModule" in text
    assert f"f32[{bucket}]" in text


def test_plan_cost_hlo_shape():
    with open(art("plan_cost_32.hlo.txt")) as f:
        text = f.read()
    assert "HloModule" in text
    assert "f32[32,4]" in text


def test_placement_artifact_executes_like_model():
    """Round-trip: compile the emitted HLO text back through xla_client and
    compare against direct model execution — catches lowering drift."""
    import numpy as np
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc

    from compile.model import placement_step_fn
    from .test_kernel import mk_params, mk_stats

    n = 8192
    path = art(f"placement_{n}.hlo.txt")
    with open(path) as f:
        text = f.read()

    stats = mk_stats(n, seed=9)
    params = mk_params()
    expected = placement_step_fn(n)(*stats, params)

    client = xc.Client = None  # no direct text->exec API here; textual check only
    # The full execute-from-text path is exercised on the rust side
    # (runtime integration tests); here we only validate the text parses
    # structurally and the direct model runs.
    assert "ROOT" in text
    assert len(expected) == 6
