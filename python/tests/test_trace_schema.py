# Schema guard for trace JSONL streams (DESIGN.md §15) — stdlib-only,
# dual-use:
#
#  * under pytest it validates the committed fixture
#    rust/tests/fixtures/trace/sample.jsonl, pinning the wire format the
#    rust side emits (render_line) and `hyplacer trace` consumes;
#  * as a script (`python3 python/tests/test_trace_schema.py FILE`) it
#    validates an arbitrary trace artifact — CI runs it against the
#    JSONL a real `--trace` run just wrote, so the schema the repo
#    documents is the schema the binary ships.
#
# Checked invariants:
#  * every line is a JSON object carrying the versioned envelope
#    {v, kind, epoch, t, seq} with v == 1;
#  * every kind is known and carries its required fields with the right
#    types (page.tier is the one optional field);
#  * seq is strictly increasing across the whole file (one global
#    emission order);
#  * epoch is nondecreasing *within a segment* and t never runs
#    backwards within a segment — a `header` starts a new segment (the
#    sim clock restarts per compare segment), so both reset there.
from __future__ import annotations

import json
import os
import sys

FIXTURE = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures", "trace", "sample.jsonl"
)

SCHEMA_VERSION = 1

NUM = (int, float)
# kind -> {field: allowed types}; page.tier is optional (place steps only)
REQUIRED = {
    "header": {
        "policy": str,
        "workload": str,
        "seed": NUM,
        "epochs": NUM,
        "epoch_secs": NUM,
    },
    "epoch_begin": {"offered_bytes": NUM},
    "fault_arm": {"fault": str, "value": NUM},
    "shard_task": {"tenant": str, "offered_bytes": NUM, "active_pages": NUM},
    "policy_tick": {
        "promote": NUM,
        "demote": NUM,
        "exchange_pairs": NUM,
        "safe_mode": bool,
    },
    "migrate_submit": {
        "accepted": NUM,
        "dropped_duplicate": NUM,
        "dropped_pinned": NUM,
    },
    "migrate_exec": {
        "promoted": NUM,
        "demoted": NUM,
        "exchanged_pairs": NUM,
        "skipped": NUM,
        "stale": NUM,
        "retried": NUM,
        "failed": NUM,
        "over_quota": NUM,
        "deferred": NUM,
    },
    "quota_reject": {"count": NUM},
    "page": {"page": NUM, "step": str},
    "tenant_epoch": {"tenant": str, "app_bytes": NUM, "dram_share": NUM},
    "safe_mode": {"entered": bool},
    "epoch_end": {
        "wall_secs": NUM,
        "app_bytes": NUM,
        "throughput": NUM,
        "dram_occupancy": NUM,
        "queue_depth": NUM,
        "safe_mode": bool,
    },
}

OPTIONAL = {"page": {"tier": str}}

PAGE_STEPS = {
    "place",
    "submit",
    "duplicate",
    "pinned_drop",
    "backoff",
    "stale",
    "skip",
    "retry",
    "fail",
    "over_quota",
    "promote",
    "demote",
    "exchange",
    "defer",
}


def validate(path):
    """Validate one trace JSONL file; returns the number of events.

    Raises AssertionError with a `path:line:` prefixed message on the
    first violation.
    """
    events = 0
    last_seq = None
    # per-segment monotonicity state; a header resets both
    seg_epoch = None
    seg_t = None
    with open(path) as f:
        for lineno, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            where = f"{path}:{lineno}:"
            ev = json.loads(line)
            assert isinstance(ev, dict), f"{where} not a JSON object"
            for key in ("v", "kind", "epoch", "t", "seq"):
                assert key in ev, f"{where} missing envelope key {key!r}"
            assert ev["v"] == SCHEMA_VERSION, f"{where} v={ev['v']!r}, want {SCHEMA_VERSION}"
            kind = ev["kind"]
            assert kind in REQUIRED, f"{where} unknown kind {kind!r}"
            for key in ("epoch", "t", "seq"):
                assert isinstance(ev[key], NUM) and not isinstance(
                    ev[key], bool
                ), f"{where} envelope key {key!r} must be numeric"

            spec = REQUIRED[kind]
            for field, types in spec.items():
                assert field in ev, f"{where} {kind} missing field {field!r}"
                val = ev[field]
                if types is not bool and isinstance(val, bool):
                    raise AssertionError(f"{where} {kind}.{field} must be numeric, got bool")
                assert isinstance(val, types), f"{where} {kind}.{field} has type {type(val).__name__}"
            allowed = set(spec) | set(OPTIONAL.get(kind, {})) | {"v", "kind", "epoch", "t", "seq"}
            extra = set(ev) - allowed
            assert not extra, f"{where} {kind} carries undocumented fields {sorted(extra)}"
            for field, types in OPTIONAL.get(kind, {}).items():
                if field in ev:
                    assert isinstance(ev[field], types), f"{where} {kind}.{field} bad type"
            if kind == "page":
                assert ev["step"] in PAGE_STEPS, f"{where} unknown page step {ev['step']!r}"

            # ordering: seq is one global strictly-increasing counter ...
            if last_seq is not None:
                assert ev["seq"] > last_seq, f"{where} seq {ev['seq']} not > {last_seq}"
            last_seq = ev["seq"]
            # ... while epoch/t restart with the sim clock at each header
            if kind == "header":
                seg_epoch, seg_t = ev["epoch"], ev["t"]
            else:
                if seg_epoch is not None:
                    assert (
                        ev["epoch"] >= seg_epoch
                    ), f"{where} epoch {ev['epoch']} ran backwards (was {seg_epoch})"
                    assert ev["t"] >= seg_t, f"{where} t {ev['t']} ran backwards (was {seg_t})"
                seg_epoch, seg_t = ev["epoch"], ev["t"]
            events += 1
    assert events > 0, f"{path}: trace is empty"
    return events


def test_committed_fixture_is_schema_valid():
    events = validate(FIXTURE)
    assert events == 21


def test_fixture_covers_every_event_kind():
    # the fixture is the schema's executable documentation: if a new
    # kind joins the taxonomy, it must appear here (and in DESIGN.md §15)
    kinds = set()
    with open(FIXTURE) as f:
        for line in f:
            if line.strip():
                kinds.add(json.loads(line)["kind"])
    assert kinds == set(REQUIRED), f"fixture kinds {sorted(kinds)} != taxonomy"


def test_validator_rejects_broken_streams(tmp_path):
    import pytest

    def check(name, lines, match):
        p = tmp_path / name
        p.write_text("\n".join(lines) + "\n")
        with pytest.raises(AssertionError, match=match):
            validate(str(p))

    good = '{"epoch":0,"kind":"epoch_begin","offered_bytes":1,"seq":0,"t":0,"v":1}'
    check("v.jsonl", ['{"epoch":0,"kind":"epoch_begin","offered_bytes":1,"seq":0,"t":0,"v":9}'], "v=9")
    check("envelope.jsonl", ['{"kind":"epoch_begin","offered_bytes":1,"seq":0,"t":0,"v":1}'], "missing envelope key 'epoch'")
    check("kind.jsonl", ['{"epoch":0,"kind":"warp_drive","seq":0,"t":0,"v":1}'], "unknown kind")
    check("field.jsonl", ['{"epoch":0,"kind":"epoch_begin","seq":0,"t":0,"v":1}'], "missing field 'offered_bytes'")
    check(
        "seq.jsonl",
        [good, '{"epoch":0,"kind":"epoch_begin","offered_bytes":1,"seq":0,"t":0,"v":1}'],
        "seq 0 not > 0",
    )
    check(
        "epoch.jsonl",
        [
            '{"epoch":3,"kind":"epoch_begin","offered_bytes":1,"seq":0,"t":3,"v":1}',
            '{"epoch":1,"kind":"epoch_begin","offered_bytes":1,"seq":1,"t":3.5,"v":1}',
        ],
        "epoch 1 ran backwards",
    )
    check("empty.jsonl", [""], "trace is empty")


def test_epoch_monotonicity_resets_at_headers(tmp_path):
    # a compare trace restarts the sim clock per policy segment: epoch 5
    # followed by a header at epoch 0 is legal, the same drop without a
    # header is not
    header = '{"epoch":0,"epoch_secs":1,"epochs":1,"kind":"header","policy":"p","seed":1,"seq":%d,"t":0,"v":1,"workload":"w"}'
    end5 = '{"app_bytes":1,"dram_occupancy":0,"epoch":5,"kind":"epoch_end","queue_depth":0,"safe_mode":false,"seq":1,"t":5,"throughput":1,"v":1,"wall_secs":1}'
    p = tmp_path / "reset.jsonl"
    p.write_text("\n".join([header % 0, end5, header % 2]) + "\n")
    assert validate(str(p)) == 3


if __name__ == "__main__":
    if len(sys.argv) != 2:
        sys.exit(f"usage: {sys.argv[0]} TRACE.jsonl")
    n = validate(sys.argv[1])
    print(f"trace schema ok: {n} event(s) in {sys.argv[1]}")
