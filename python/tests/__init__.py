# Marks tests/ as a package so pytest imports modules as tests.<name>,
# which is what test_golden.py's relative import (.test_kernel) needs.
