# pytest: L1 pallas kernel vs pure-jnp ref — the CORE correctness signal.
#
# hypothesis sweeps shapes, block sizes, parameter points and degenerate
# stat distributions; every case asserts allclose between
# kernels.classify.classify_pages (pallas, interpret=True) and
# kernels.ref.classify_pages_ref.
from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.classify import (
    CLASS_COLD,
    CLASS_READ,
    CLASS_WRITE,
    N_PARAMS,
    PARAM_AGE_WEIGHT,
    PARAM_ALPHA,
    PARAM_COLD_BIAS,
    PARAM_HOT_THRESH,
    PARAM_WR_THRESH,
    PARAM_WR_WEIGHT,
    classify_pages,
)
from compile.kernels.ref import classify_pages_ref


def mk_params(
    alpha=0.3, hot=0.2, wr=0.3, wr_weight=0.5, cold_bias=0.25, age_weight=0.7
):
    p = np.zeros(N_PARAMS, dtype=np.float32)
    p[PARAM_ALPHA] = alpha
    p[PARAM_HOT_THRESH] = hot
    p[PARAM_WR_THRESH] = wr
    p[PARAM_WR_WEIGHT] = wr_weight
    p[PARAM_COLD_BIAS] = cold_bias
    p[PARAM_AGE_WEIGHT] = age_weight
    return jnp.asarray(p)


def mk_stats(n, seed=0, bit_density=0.5, valid_density=0.9):
    rng = np.random.default_rng(seed)
    ref = (rng.random(n) < bit_density).astype(np.float32)
    dirty = (rng.random(n) < bit_density * 0.5).astype(np.float32)
    hot = rng.random(n, dtype=np.float32)
    wr = rng.random(n, dtype=np.float32)
    tier = (rng.random(n) < 0.5).astype(np.float32)
    valid = (rng.random(n) < valid_density).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (ref, dirty, hot, wr, tier, valid))


def run_both(stats, params, block):
    out_k = classify_pages(*stats, params, block=block)
    out_r = classify_pages_ref(*stats, params)
    return [np.asarray(a) for a in out_k], [np.asarray(a) for a in out_r]


def assert_match(out_k, out_r):
    names = ["new_hot", "new_wr", "class", "demote", "promote"]
    for name, a, b in zip(names, out_k, out_r):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6, err_msg=name)


@pytest.mark.parametrize("n,block", [(256, 256), (1024, 256), (8192, 1024), (8192, 8192)])
def test_kernel_matches_ref_shapes(n, block):
    stats = mk_stats(n, seed=n)
    out_k, out_r = run_both(stats, mk_params(), block)
    assert_match(out_k, out_r)


def test_kernel_multi_block_equals_single_block():
    stats = mk_stats(2048, seed=7)
    multi = classify_pages(*stats, mk_params(), block=256)
    single = classify_pages(*stats, mk_params(), block=2048)
    for a, b in zip(multi, single):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-7)


def test_rejects_non_multiple_block():
    stats = mk_stats(100)
    with pytest.raises(ValueError):
        classify_pages(*stats, mk_params(), block=64)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([128, 256, 512]),
    alpha=st.floats(0.01, 1.0),
    hot=st.floats(0.0, 1.0),
    wr=st.floats(0.0, 1.0),
    wr_weight=st.floats(0.0, 2.0),
    cold_bias=st.floats(0.0, 1.0),
    age_weight=st.floats(0.0, 1.0),
    bit_density=st.floats(0.0, 1.0),
    valid_density=st.floats(0.0, 1.0),
)
def test_kernel_matches_ref_hypothesis(
    seed, n_blocks, block, alpha, hot, wr, wr_weight, cold_bias, age_weight,
    bit_density, valid_density,
):
    n = n_blocks * block
    stats = mk_stats(n, seed=seed, bit_density=bit_density, valid_density=valid_density)
    params = mk_params(alpha, hot, wr, wr_weight, cold_bias, age_weight)
    out_k, out_r = run_both(stats, params, block)
    assert_match(out_k, out_r)


# ----- semantic invariants (on the kernel itself) -----


def test_invalid_pages_zeroed_and_excluded():
    n = 256
    stats = list(mk_stats(n, seed=3))
    stats[5] = jnp.zeros(n, dtype=jnp.float32)  # all invalid
    out = classify_pages(*stats, mk_params(), block=n)
    new_hot, new_wr, cls, demote, promote = [np.asarray(a) for a in out]
    assert (new_hot == 0).all() and (new_wr == 0).all()
    assert (cls == CLASS_COLD).all()
    assert (demote == -1.0).all() and (promote == -1.0).all()


def test_class_partition_by_tier_masking():
    n = 512
    stats = mk_stats(n, seed=11)
    out = classify_pages(*stats, mk_params(), block=n)
    _, _, _, demote, promote = [np.asarray(a) for a in out]
    tier = np.asarray(stats[4])
    valid = np.asarray(stats[5])
    live_dram = (tier < 0.5) & (valid > 0.5)
    live_pm = (tier >= 0.5) & (valid > 0.5)
    # demote scores only on live DRAM pages, promote only on live PM pages
    assert (demote[~live_dram] == -1.0).all()
    assert (demote[live_dram] >= 0.0).all()
    assert (promote[~live_pm] == -1.0).all()
    assert (promote[live_pm] >= 0.0).all()


def test_ewma_decay_monotone():
    """A page never touched again decays toward zero; a page touched every
    window converges toward one."""
    n = 128
    params = mk_params(alpha=0.4)
    hot = jnp.full((n,), 0.8, dtype=jnp.float32)
    wr = jnp.zeros(n, dtype=jnp.float32)
    zeros = jnp.zeros(n, dtype=jnp.float32)
    ones = jnp.ones(n, dtype=jnp.float32)
    prev = hot
    for _ in range(6):
        out = classify_pages(zeros, zeros, prev, wr, zeros, ones,
                             params, block=n)
        nxt = out[0]
        assert float(jnp.max(nxt)) < float(jnp.max(prev))
        prev = nxt
    assert float(jnp.max(prev)) < 0.05
    prev = jnp.zeros(n, dtype=jnp.float32)
    for _ in range(12):
        out = classify_pages(ones, zeros, prev, wr, zeros, ones, params, block=n)
        prev = out[0]
    assert float(jnp.min(prev)) > 0.95


def test_write_pages_require_hotness():
    """A dirty-but-globally-cold page must not classify as write-intensive."""
    n = 128
    params = mk_params(alpha=0.05, hot=0.5)
    zeros = jnp.zeros(n, dtype=jnp.float32)
    ones = jnp.ones(n, dtype=jnp.float32)
    out = classify_pages(zeros, ones, zeros, zeros, zeros, ones, params, block=n)
    cls = np.asarray(out[2])
    assert (cls == CLASS_COLD).all()


def test_hot_write_page_classifies_write():
    n = 128
    params = mk_params(alpha=0.5, hot=0.2, wr=0.3)
    ones = jnp.ones(n, dtype=jnp.float32)
    hot = jnp.full((n,), 0.9, dtype=jnp.float32)
    out = classify_pages(ones, ones, hot, hot, jnp.zeros(n, jnp.float32), ones,
                         params, block=n)
    assert (np.asarray(out[2]) == CLASS_WRITE).all()


def test_hot_readonly_page_classifies_read():
    n = 128
    params = mk_params(alpha=0.5, hot=0.2, wr=0.3)
    ones = jnp.ones(n, dtype=jnp.float32)
    zeros = jnp.zeros(n, dtype=jnp.float32)
    hot = jnp.full((n,), 0.9, dtype=jnp.float32)
    out = classify_pages(ones, zeros, hot, zeros, zeros, ones, params, block=n)
    assert (np.asarray(out[2]) == CLASS_READ).all()


def test_demote_prefers_cold_over_hot():
    """Observation 2: among DRAM pages the coldest, most read-dominated
    ones must score highest for demotion."""
    n = 128
    params = mk_params()
    zeros = jnp.zeros(n, dtype=jnp.float32)
    ones = jnp.ones(n, dtype=jnp.float32)
    hot = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    out = classify_pages(zeros, zeros, hot, zeros, zeros, ones, params, block=n)
    demote = np.asarray(out[3])
    assert (np.diff(demote) <= 1e-6).all()  # hotter -> lower demote score


def test_promote_prefers_write_intensive():
    """Among equally hot PM pages, write-dominated ones must score higher
    for promotion (wr_weight > 0)."""
    n = 128
    params = mk_params(wr_weight=0.8)
    zeros = jnp.zeros(n, dtype=jnp.float32)
    ones = jnp.ones(n, dtype=jnp.float32)
    hot = jnp.full((n,), 0.6, dtype=jnp.float32)
    wr = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    out = classify_pages(zeros, zeros, hot, wr, ones, ones, params, block=n)
    promote = np.asarray(out[4])
    assert (np.diff(promote) >= -1e-6).all()


def test_dirty_implies_touched():
    """A dirty bit with a racing cleared R bit still counts as an access."""
    n = 128
    params = mk_params(alpha=1.0, hot=0.5)
    zeros = jnp.zeros(n, dtype=jnp.float32)
    ones = jnp.ones(n, dtype=jnp.float32)
    out = classify_pages(zeros, ones, zeros, zeros, zeros, ones, params, block=n)
    np.testing.assert_allclose(np.asarray(out[0]), 1.0)
