# Golden-vector bridge between the python oracle and the rust native
# fallback (rust/src/policies/hyplacer/native.rs).
#
# Generates a deterministic input set, runs the pure-jnp oracle, and writes
# tests/golden/classify_golden.json (if absent). The committed file is then
# verified against the oracle on every pytest run; the rust unit test
# `native::tests::golden_matches_python_oracle` loads the same file and
# asserts its scalar implementation matches to 1e-5.
from __future__ import annotations

import json
import os

import numpy as np

from compile.kernels.ref import classify_pages_ref
from .test_kernel import mk_params, mk_stats

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "classify_golden.json")
N = 96


def build_golden():
    stats = mk_stats(N, seed=42, bit_density=0.6, valid_density=0.85)
    params = mk_params(
        alpha=0.35, hot=0.25, wr=0.4, wr_weight=0.6, cold_bias=0.2, age_weight=0.65
    )
    out = classify_pages_ref(*stats, params)
    names_in = ["ref", "dirty", "hot_ewma", "wr_ewma", "tier", "valid"]
    names_out = ["new_hot", "new_wr", "page_class", "demote_score", "promote_score"]
    doc = {
        "n": N,
        "params": [float(x) for x in np.asarray(params)],
        "inputs": {k: [float(x) for x in np.asarray(v)] for k, v in zip(names_in, stats)},
        "outputs": {k: [float(x) for x in np.asarray(v)] for k, v in zip(names_out, out)},
    }
    return doc


def test_golden_file_matches_oracle():
    doc = build_golden()
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    if not os.path.exists(GOLDEN_PATH):
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1)
    with open(GOLDEN_PATH) as f:
        committed = json.load(f)
    assert committed["n"] == doc["n"]
    np.testing.assert_allclose(committed["params"], doc["params"], rtol=1e-6)
    for k, v in doc["inputs"].items():
        np.testing.assert_allclose(committed["inputs"][k], v, rtol=1e-6, err_msg=k)
    for k, v in doc["outputs"].items():
        np.testing.assert_allclose(
            committed["outputs"][k], v, rtol=1e-5, atol=1e-6, err_msg=k
        )
