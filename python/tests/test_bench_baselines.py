# Schema guard for the committed perf baselines (BENCH_*.json at the
# repo root) — stdlib-only, so it runs even where jax/numpy are absent
# and keeps the python suite from collecting zero tests there.
#
# The rust side owns the semantics (bench_harness/baseline.rs); this
# guard catches hand-edits that would silently disable the CI gate:
# unknown metric kinds, non-numeric values, a wrong mode, or docs that
# no longer gate on anything.
from __future__ import annotations

import json
import os

import pytest

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")
BASELINES = ["BENCH_hotpath.json", "BENCH_sweep.json"]
KINDS = {"exact", "ratio", "info"}


@pytest.mark.parametrize("name", BASELINES)
def test_baseline_doc_schema(name):
    path = os.path.join(REPO_ROOT, name)
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == 1
    assert doc["bench"] in {"hotpath", "sweep"}
    assert name == f"BENCH_{doc['bench']}.json"
    # CI regenerates in smoke mode; a full-mode baseline would never match
    assert doc["mode"] == "quick"
    assert isinstance(doc["metrics"], dict) and doc["metrics"]
    gating = 0
    for metric, m in doc["metrics"].items():
        assert m["kind"] in KINDS, f"{name}: {metric}: bad kind {m['kind']!r}"
        assert isinstance(m["value"], (int, float)), f"{name}: {metric}"
        if m["kind"] != "info":
            gating += 1
    assert gating > 0, f"{name} gates on nothing"
    assert all(isinstance(k, str) for k in doc.get("cell_keys", []))


def test_hotpath_carries_the_decision_tick_instruments():
    # PR 1's MMU-side proxy (RNG draws) gained a kernel-side twin in the
    # activity-index PR: the decision tick's PTE-visit metrics must stay
    # in the committed doc so bench-check keeps gating the O(touched +
    # selected) guarantee.
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    assert "sparse/pte_visits_per_epoch" in metrics
    flag = metrics["sparse/pte_visits_scale_free"]
    # the scale-free property is a hand-derivable exact boolean: it must
    # gate (not info) and hold (value 1)
    assert flag["kind"] == "exact"
    assert flag["value"] == 1


def test_hotpath_carries_the_migration_engine_metrics():
    # The bandwidth-throttled migration engine (DESIGN.md §9) must keep
    # its queue telemetry in the committed doc so bench-check covers the
    # pipeline: budget compliance and zero stale drops gate exactly (both
    # hold by construction); queue depth / deferral gate after the first
    # reference-runner recapture.
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    for name in (
        "migrate/queue_depth_peak",
        "migrate/deferred_ratio",
        "migrate/stale_drop_ratio",
        "migrate/throttle_respected",
    ):
        assert name in metrics, f"missing {name}"
    assert metrics["migrate/stale_drop_ratio"]["kind"] == "exact"
    assert metrics["migrate/stale_drop_ratio"]["value"] == 0
    assert metrics["migrate/throttle_respected"]["kind"] == "exact"
    assert metrics["migrate/throttle_respected"]["value"] == 1


def test_hotpath_carries_the_mix_fairness_metrics():
    # The per-tenant quota PR promoted the co-run fairness view to
    # first-class hotpath metrics: unfairness and weighted speedup of a
    # hard-capped two-tenant mix under hyplacer-qos, plus the engine's
    # over-quota rejection counter. They stay info-kind until the first
    # reference-runner recapture (the collector already emits the two
    # ratios as gated — same upgrade path as the migrate/* metrics).
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    for name in (
        "mix/unfairness",
        "mix/weighted_speedup",
        "mix/over_quota_rejections",
    ):
        assert name in metrics, f"missing {name}"


def test_hotpath_carries_the_fault_injection_metrics():
    # The fault-injection PR (DESIGN.md §13) put the degraded-mode view
    # in the hotpath doc: the storm run's retry ratio, the PINNED
    # exclusion counter (exactly 0 — policies must never plan unmovable
    # pages), and HyPlacer's safe-mode dwell. They stay info-kind until
    # the first reference-runner recapture, like the mix/* metrics.
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    for name in (
        "faults/retry_ratio",
        "faults/pinned_rejections",
        "faults/safe_mode_epochs",
    ):
        assert name in metrics, f"missing {name}"
    assert metrics["faults/pinned_rejections"]["value"] == 0


def test_hotpath_carries_the_shard_metrics():
    # The sharded touch-phase PR (DESIGN.md §14) gates its bit-identity
    # contract from the hotpath doc: result_invariant is exact and must
    # be 1 (shard_jobs 4 reproduced the sequential run bit for bit);
    # touch_speedup is a host-dependent wall ratio and stays info-kind
    # permanently — sharding must never be justified by broken results.
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    for name in ("shard/result_invariant", "shard/touch_speedup"):
        assert name in metrics, f"missing {name}"
    assert metrics["shard/result_invariant"]["kind"] == "exact"
    assert metrics["shard/result_invariant"]["value"] == 1
    assert metrics["shard/touch_speedup"]["kind"] == "info"


def test_hotpath_carries_the_trace_metrics():
    # The run-tracing PR (DESIGN.md §15) gates its observer-effect
    # contract from the hotpath doc: observer_effect_zero is exact and
    # must be 1 (the traced re-run of the throttled cg-M cell is
    # bit-identical to the untraced one); events_per_epoch is the emitted
    # volume and stays info-kind permanently — it legitimately moves
    # whenever the event taxonomy grows.
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = json.load(f)
    metrics = doc["metrics"]
    for name in ("trace/observer_effect_zero", "trace/events_per_epoch"):
        assert name in metrics, f"missing {name}"
    assert metrics["trace/observer_effect_zero"]["kind"] == "exact"
    assert metrics["trace/observer_effect_zero"]["value"] == 1
    assert metrics["trace/events_per_epoch"]["kind"] == "info"


def test_baselines_never_gate_on_wall_clock():
    # the whole point of ratio baselines: host timings stay informational
    for name in BASELINES:
        with open(os.path.join(REPO_ROOT, name)) as f:
            doc = json.load(f)
        for metric, m in doc["metrics"].items():
            if metric.startswith("host/"):
                assert m["kind"] == "info", f"{name}: {metric} must not gate"
