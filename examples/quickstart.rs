//! Quickstart: bind one workload to HyPlacer on the simulated
//! DRAM+DCPMM machine and print the run summary.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::run_pair;
use hyplacer::{policies, workloads};

fn main() {
    // The paper's machine: one socket, 32 GB DDR4 + 256 GB DCPMM.
    let machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 80;

    // A medium CG run (39.8 GB footprint, ~1.25x DRAM size).
    let hp = HyPlacerConfig::default();
    let window_frac = hp.delay_secs / sim.epoch_secs;

    println!("workload  CG-M (39.8 GB footprint, 32 GB DRAM)\n");
    let mut baseline = None;
    for policy in ["adm-default", "hyplacer"] {
        let w = workloads::by_name("cg-M", machine.page_bytes, sim.epoch_secs).unwrap();
        let p = policies::by_name(policy, &machine, &hp).unwrap();
        let r = run_pair(&machine, &sim, w, p, window_frac);
        println!(
            "{:<12} wall {:>7.1}s  throughput {:>6.2} GB/s  DRAM share {:>5.1}%  migrated {:>6} pages",
            r.policy,
            r.total_wall_secs,
            r.throughput / 1e9,
            r.dram_traffic_share * 100.0,
            r.migrated_pages
        );
        if policy == "adm-default" {
            baseline = Some(r);
        } else if let Some(base) = &baseline {
            println!(
                "\nHyPlacer: {:.2}x whole-run, {:.2}x steady-state speedup vs Linux \
                 default placement (energy gain {:.2}x)",
                r.speedup_vs(base),
                r.steady_speedup_vs(base),
                r.energy_gain_vs(base)
            );
        }
    }
}
