//! MLC insight study: regenerate the paper's §3 empirical analysis
//! (Fig. 2 response surfaces + Fig. 3 bandwidth-balance study) and print
//! the three Observations with measured evidence.
//!
//! ```bash
//! cargo run --release --example mlc_study
//! ```

use hyplacer::bench_harness::{fig2, fig3};
use hyplacer::config::{MachineConfig, Tier, GB};
use hyplacer::mem::PerfModel;

fn main() {
    let machine = MachineConfig::paper_machine();

    // ---- Fig. 2: open-loop characterization --------------------------
    let rep2 = fig2::report(&machine);
    println!("{}", rep2.render());

    // ---- Observation 1: partitioned-policy cost ----------------------
    // read-only pages stranded in DCPMM vs served from free DRAM
    let model = PerfModel::new(&machine);
    let demand = 12.0 * GB;
    let (_, lat_pm) = model.characterize(Tier::Pm, demand, 0.0, 0.0);
    let (_, lat_dram) = model.characterize(Tier::Dram, demand, 0.0, 0.0);
    println!(
        "Observation 1 (partitioned policy): read-only pages in DCPMM pay \
         {:.1}x the latency of free DRAM at {:.0} GB/s demand\n",
        lat_pm / lat_dram,
        demand / GB
    );

    // ---- Observation 2: read/write awareness -------------------------
    let (bw_r, _) = model.characterize(Tier::Pm, 30.0 * GB, 0.0, 0.0);
    let (bw_w, _) = model.characterize(Tier::Pm, 30.0 * GB, 1.0 / 3.0, 0.0);
    println!(
        "Observation 2 (r/w awareness): at 30 GB/s offered, DCPMM sustains \
         {:.1} GB/s all-reads but only {:.1} GB/s at 2R:1W — keeping \
         write-intensive pages in DRAM matters\n",
        bw_r / GB,
        bw_w / GB
    );

    // ---- Fig. 3 / Observation 3: bandwidth balance -------------------
    let rep3 = fig3::report();
    println!("{}", rep3.render());
}
