//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! Pipeline: `make artifacts` compiled the Pallas classification kernel
//! (L1) inside the JAX placement model (L2) to HLO text; this binary
//! loads it through PJRT, plugs it into HyPlacer's Control loop (L3) as
//! the classifier, replays a recorded CG-L workload trace through the
//! simulated DRAM+DCPMM machine, and reports the paper's headline
//! metric — steady-state speedup over Linux's default placement — for
//! BOTH the AOT and the native classifier, asserting they agree.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_placement
//! ```
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::{run_pair, SimResult};
use hyplacer::policies::hyplacer::HyPlacer;
use hyplacer::policies::{self, Policy};
use hyplacer::runtime::placement::AotClassifier;
use hyplacer::runtime::default_artifacts_dir;
use hyplacer::workloads::trace::{Trace, TraceWorkload};
use hyplacer::workloads::{self, Workload};

const EPOCHS: u32 = 120;

fn run(
    machine: &MachineConfig,
    sim: &SimConfig,
    trace: &Trace,
    policy: Box<dyn Policy>,
    window_frac: f64,
) -> SimResult {
    let w: Box<dyn Workload> = Box::new(TraceWorkload::new(trace.clone()));
    run_pair(machine, sim, w, policy, window_frac)
}

fn main() {
    let machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = EPOCHS;
    sim.warmup_epochs = EPOCHS / 3;
    let hp = HyPlacerConfig::default();
    let window_frac = hp.delay_secs / sim.epoch_secs;

    // 1. Record a real workload trace (CG-L: 150 GB, 3.5x DRAM) so every
    //    policy replays *identical* demand.
    let mut live = workloads::by_name("cg-L", machine.page_bytes, sim.epoch_secs).unwrap();
    let trace = Trace::record(live.as_mut(), EPOCHS);
    println!(
        "trace: {} epochs of {} ({} pages footprint)\n",
        EPOCHS, trace.name, trace.footprint_pages
    );

    // 2. Baseline: Linux default first-touch placement.
    let base = run(
        &machine,
        &sim,
        &trace,
        policies::by_name("adm-default", &machine, &hp).unwrap(),
        window_frac,
    );
    println!(
        "adm-default      : {:>6.2} GB/s steady  ({:.1}s total wall)",
        base.steady_throughput / 1e9,
        base.total_wall_secs
    );

    // 3. HyPlacer with the NATIVE classifier.
    let native = run(
        &machine,
        &sim,
        &trace,
        policies::by_name("hyplacer", &machine, &hp).unwrap(),
        window_frac,
    );
    println!(
        "hyplacer(native) : {:>6.2} GB/s steady  => {:.2}x speedup",
        native.steady_throughput / 1e9,
        native.steady_speedup_vs(&base)
    );

    // 4. HyPlacer with the AOT/PJRT classifier — the full 3-layer stack.
    let dir = default_artifacts_dir();
    let aot = match AotClassifier::new(&dir) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("artifacts not built ({e:#}); run `make artifacts` first");
            std::process::exit(2);
        }
    };
    let policy: Box<dyn Policy> =
        Box::new(HyPlacer::new(&machine, hp.clone()).with_classifier(Box::new(aot)));
    let aot_run = run(&machine, &sim, &trace, policy, window_frac);
    println!(
        "hyplacer(aot)    : {:>6.2} GB/s steady  => {:.2}x speedup  [PJRT classifier]",
        aot_run.steady_throughput / 1e9,
        aot_run.steady_speedup_vs(&base)
    );

    // 5. The two classifier paths must agree (same math, fp32).
    let native_speedup = native.steady_speedup_vs(&base);
    let aot_speedup = aot_run.steady_speedup_vs(&base);
    let rel = (native_speedup - aot_speedup).abs() / native_speedup;
    println!(
        "\nAOT vs native agreement: {:.3}x vs {:.3}x (rel diff {:.4})",
        aot_speedup, native_speedup, rel
    );
    assert!(rel < 0.02, "AOT and native classifier paths diverged");
    assert!(aot_speedup > 1.8, "headline speedup too low: {aot_speedup}");
    println!(
        "\nE2E OK — headline: HyPlacer {:.2}x vs ADM-default on CG-L \
         (paper: up to 11x on its testbed; see EXPERIMENTS.md §Fig5)",
        aot_speedup
    );
}
