//! Multi-tenant co-run demo: two workloads share one socket — DRAM
//! capacity, the migration queue and the memory system are global —
//! and the placement policy arbitrates between them system-wide.
//!
//! Like the other repo-root examples this file is illustrative (not a
//! cargo target); the equivalent live commands are
//!
//! ```bash
//! hyplacer run -w 'is.M+pr.M' --config configs/mix_demo.toml
//! hyplacer compare -w 'is.M+pr.M' --config configs/mix_demo.toml
//! ```
//!
//! and the claim below — HyPlacer beats ADM-default on aggregate
//! weighted speedup — is pinned by
//! `tests/tenants.rs::hyplacer_beats_adm_default_on_mix_weighted_speedup`.
//!
//! IS-M (write-heavy integer sort, 44 GB) co-runs with PR-M (PageRank,
//! 48 GB) — 92 GB combined over a 32 GB DRAM tier. Under first-touch
//! (adm-default) the first tenant grabs all of DRAM and the second is
//! stranded in DCPMM; HyPlacer's system-wide tick promotes each
//! tenant's hot set on merit.

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::SimResult;
use hyplacer::policies;
use hyplacer::tenants::{run_mix, run_mix_with_solos, MixSpec};

fn main() {
    let machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = 40;
    sim.warmup_epochs = 8;
    let hp = HyPlacerConfig::default();
    let window_frac = hp.delay_secs / sim.epoch_secs;
    let mix = MixSpec::parse("is.M+pr.M").unwrap();

    println!("mix  IS-M + PR-M (92 GB combined, 32 GB DRAM)\n");

    // adm-default: co-run + solos. The adm solos double as the COMMON
    // reference for the cross-policy aggregate (the scheduling-
    // literature weighted-speedup normalization) — per-policy own-solo
    // ratios measure contention degradation and are NOT comparable
    // across policies, because each policy's solo baseline differs.
    let adm = run_mix_with_solos(&machine, &sim, &mix, window_frac, || {
        policies::by_name("adm-default", &machine, &hp).unwrap()
    })
    .unwrap();
    let hyp = run_mix(
        &machine,
        &sim,
        &mix,
        policies::by_name("hyplacer", &machine, &hp).unwrap(),
        window_frac,
    )
    .unwrap();

    let weighted_vs_adm_solo = |corun: &SimResult| -> f64 {
        let mut sum = 0.0;
        let mut wsum = 0.0;
        for (t, solo) in corun.tenants.iter().zip(adm.solos.iter()) {
            sum += t.share_weight * (t.steady_throughput / solo.steady_throughput);
            wsum += t.share_weight;
        }
        sum / wsum
    };

    for (label, corun) in [("adm-default", &adm.corun), ("hyplacer", &hyp)] {
        println!(
            "{label:<12} wall {:>7.1}s  weighted speedup vs adm-solo {:>5.3}",
            corun.total_wall_secs,
            weighted_vs_adm_solo(corun)
        );
        for t in &corun.tenants {
            println!(
                "    {:<6} steady {:>6.2} GB/s  DRAM share {:>5.1}%",
                t.name,
                t.steady_throughput / 1e9,
                t.mean_dram_share * 100.0
            );
        }
        println!();
    }
    println!(
        "adm-default contention view: unfairness {:.2}x (slowdowns vs its own solos: {:?})",
        adm.unfairness,
        adm.slowdowns.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>()
    );
    println!("HyPlacer arbitrates DRAM across tenants; first-touch strands the late one.");
}
