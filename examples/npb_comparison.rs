//! NPB comparison: run every placement policy of the paper's evaluation
//! on one NPB workload — one Fig. 5 column.
//!
//! ```bash
//! cargo run --release --example npb_comparison [workload] [epochs]
//! cargo run --release --example npb_comparison cg-L 150
//! ```

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::{run_pair, SimResult};
use hyplacer::policies::{self, FIG5_POLICIES};
use hyplacer::report::Table;
use hyplacer::workloads;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let workload = args.get(1).map(String::as_str).unwrap_or("cg-L");
    let epochs: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(120);

    let machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = epochs;
    sim.warmup_epochs = epochs / 3;
    let hp = HyPlacerConfig::default();
    let window_frac = hp.delay_secs / sim.epoch_secs;

    let mut table = Table::new(vec![
        "policy",
        "wall_s",
        "throughput_GBs",
        "steady_speedup",
        "energy_gain",
        "DRAM_share",
        "migrated_pages",
    ]);
    let mut base: Option<SimResult> = None;
    for pname in FIG5_POLICIES {
        let w = workloads::by_name(workload, machine.page_bytes, sim.epoch_secs)
            .unwrap_or_else(|| panic!("unknown workload {workload}"));
        let p = policies::by_name(pname, &machine, &hp).unwrap();
        let r = run_pair(&machine, &sim, w, p, window_frac);
        let (speedup, egain) = match &base {
            Some(b) => (r.steady_speedup_vs(b), r.energy_gain_vs(b)),
            None => (1.0, 1.0),
        };
        table.row(vec![
            r.policy.clone(),
            format!("{:.1}", r.total_wall_secs),
            format!("{:.2}", r.throughput / 1e9),
            format!("{speedup:.2}x"),
            format!("{egain:.2}x"),
            format!("{:.1}%", r.dram_traffic_share * 100.0),
            r.migrated_pages.to_string(),
        ]);
        if pname == "adm-default" {
            base = Some(r);
        }
    }
    println!("NPB comparison — workload {workload}, {epochs} epochs\n");
    println!("{}", table.render());
    println!("(paper Fig. 5 shape: HyPlacer wins, MemM strong, nimble/memos ~baseline)");
}
