//! GAP-style graph workloads (extension beyond the paper's NPB set):
//! PageRank and BFS on a power-law graph, comparing placement policies.
//! BFS's wandering frontier stresses slow-reacting hotness estimators.
//!
//! ```bash
//! cargo run --release --example graph_serving [epochs]
//! ```

use hyplacer::config::{HyPlacerConfig, MachineConfig, SimConfig};
use hyplacer::coordinator::{run_pair, SimResult};
use hyplacer::policies;
use hyplacer::report::Table;
use hyplacer::workloads;

fn main() {
    let epochs: u32 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    let machine = MachineConfig::paper_machine();
    let mut sim = SimConfig::default();
    sim.epochs = epochs;
    sim.warmup_epochs = epochs / 3;
    let hp = HyPlacerConfig::default();
    let window_frac = hp.delay_secs / sim.epoch_secs;

    for wname in ["pr-L", "bfs-L"] {
        let mut table =
            Table::new(vec!["policy", "throughput_GBs", "steady_speedup", "migrated"]);
        let mut base: Option<SimResult> = None;
        for pname in ["adm-default", "memm", "autonuma", "hyplacer"] {
            let w = workloads::by_name(wname, machine.page_bytes, sim.epoch_secs).unwrap();
            let p = policies::by_name(pname, &machine, &hp).unwrap();
            let r = run_pair(&machine, &sim, w, p, window_frac);
            let speedup = base.as_ref().map(|b| r.steady_speedup_vs(b)).unwrap_or(1.0);
            table.row(vec![
                r.policy.clone(),
                format!("{:.2}", r.throughput / 1e9),
                format!("{speedup:.2}x"),
                r.migrated_pages.to_string(),
            ]);
            if pname == "adm-default" {
                base = Some(r);
            }
        }
        println!("graph workload {wname}\n{}", table.render());
    }
}
